"""Attention layers.

Execution strategies, chosen by the caller:

* ``ops.fused_attention`` (kernels/ops.py) — flash-style fused gated
  attention: online softmax over KV tiles in one Pallas kernel, scores never
  materialized in HBM, recompute custom_vjp. The Evoformer's four attention
  sites route through it (core/evoformer._gated_attention).
* ``evoformer_attention`` — scores-materialized gated attention with the
  paper's fused scale+bias+mask+softmax Pallas kernel. Evoformer rows are
  short (N_r <= a few k), which is the regime the paper's kernel targets;
  kept as the A/B baseline (KernelPolicy(enabled=False), the "oracle"
  plan preset) and the TP path.
* ``blockwise_attention`` — flash-style online-softmax attention (lax.scan
  over q/kv blocks, fp32 running max/sum). Used for decoder-LM training and
  32k prefill, where scores cannot be materialized.
* ``sliding_window_attention`` — true sub-quadratic windowed attention: each
  q block dynamic-slices only the KV window it can see, so compiled FLOPs
  scale as O(S * W) not O(S^2) (gemma3 local layers, hymba, long-context).
* ``decode_attention`` — single-token query against a (possibly sharded)
  KV cache with length masking.

All strategies implement GQA by broadcasting KV heads, support bf16 inputs
with fp32 softmax statistics, and use a single merged QKV projection
(paper §IV.A.1 "Merge GEMM").
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.layers.norms import init_rms_norm, rms_norm
from repro.layers.params import Params, init_dense, trunc_normal

NEG_INF = -1e9


class AttnDims(NamedTuple):
    n_heads: int
    n_kv: int
    head_dim: int


def init_attention(
    key,
    d_model: int,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    *,
    qkv_bias: bool = False,
    out_bias: bool = False,
    gating: bool = False,
    qk_norm: bool = False,
    d_out: int | None = None,
    dtype=jnp.float32,
) -> Params:
    """Merged-QKV attention parameters (Merge GEMM, paper §IV.A.1)."""
    d_out = d_out or d_model
    ks = jax.random.split(key, 4)
    qkv_dim = (n_heads + 2 * n_kv) * head_dim
    p = {
        "wqkv": init_dense(ks[0], d_model, qkv_dim, bias=qkv_bias, dtype=dtype),
        "wo": init_dense(ks[1], n_heads * head_dim, d_out, bias=out_bias,
                         zero_init=True, dtype=dtype),
    }
    if gating:
        p["wg"] = init_dense(ks[2], d_model, n_heads * head_dim, bias=True, dtype=dtype)
        # AlphaFold convention: gate bias init to 1 => gates start open.
        p["wg"]["b"] = jnp.ones_like(p["wg"]["b"])
    if qk_norm:
        p["q_norm"] = init_rms_norm(head_dim, dtype)
        p["k_norm"] = init_rms_norm(head_dim, dtype)
    return p


def project_qkv(
    p: Params, x: jax.Array, dims: AttnDims, compute_dtype=jnp.bfloat16
):
    """x: (..., S, D) -> q (..., S, H, hd), k/v (..., S, KV, hd)."""
    h, kv, hd = dims
    y = jnp.einsum("...sd,de->...se", x.astype(compute_dtype),
                   p["wqkv"]["w"].astype(compute_dtype))
    if "b" in p["wqkv"]:
        y = y + p["wqkv"]["b"].astype(compute_dtype)
    q, k, v = jnp.split(y, [h * hd, (h + kv) * hd], axis=-1)
    q = q.reshape(q.shape[:-1] + (h, hd))
    k = k.reshape(k.shape[:-1] + (kv, hd))
    v = v.reshape(v.shape[:-1] + (kv, hd))
    if "q_norm" in p:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)
    return q, k, v


def output_proj(p: Params, ctx: jax.Array, x_for_gate: jax.Array | None = None):
    """ctx: (..., S, H, hd) -> (..., S, d_out); optional sigmoid gating."""
    dt = ctx.dtype
    flat = ctx.reshape(ctx.shape[:-2] + (-1,))
    if "wg" in p and x_for_gate is not None:
        g = jnp.einsum("...sd,de->...se", x_for_gate.astype(dt),
                       p["wg"]["w"].astype(dt))
        flat = ops.bias_sigmoid_mul(g, p["wg"]["b"], flat)
    out = jnp.einsum("...se,eo->...so", flat, p["wo"]["w"].astype(dt))
    if "b" in p["wo"]:
        out = out + p["wo"]["b"].astype(dt)
    return out


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(..., S, KV, hd) -> (..., S, H, hd) by repeating groups."""
    kv = k.shape[-2]
    if kv == n_heads:
        return k
    reps = n_heads // kv
    return jnp.repeat(k, reps, axis=-2)


# ---------------------------------------------------------------------------
# Evoformer attention: scores materialized, fused softmax kernel.
# ---------------------------------------------------------------------------

def evoformer_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
) -> jax.Array:
    """q,k,v: (N, S, H, hd); bias: (B, H, Sq, Skv) pair bias with N % B == 0
    (each bias batch element shared by N/B rows); mask: (N, Skv).

    Returns (N, Sq, H, hd). Softmax via the paper's fused kernel. This is the
    scores-materialized form — ops.fused_attention is the flash-style fused
    kernel with identical semantics (same bias/mask contract) that the
    Evoformer sites use; this one stays as the A/B oracle + TP-mode path.
    """
    hd = q.shape[-1]
    scale = 1.0 / (hd**0.5)
    scores = jnp.einsum("nqhd,nkhd->nhqk", q, k)  # bf16 MXU GEMM
    probs = ops.fused_softmax(scores, bias=bias, mask=mask, scale=scale)
    return jnp.einsum("nhqk,nkhd->nqhd", probs, v)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention for decoder LMs.
#
# custom_vjp: the forward saves only (q, k, v, out, lse); the backward
# recomputes P per KV block. Without this, the scan's default VJP stores the
# (B, H, q_block, kv_block) probability tensor for EVERY block iteration —
# the dry-run showed those stacked f32 buffers dominating the memory roofline
# term for all attention archs (EXPERIMENTS.md §Perf iteration 2).
# ---------------------------------------------------------------------------


def _flash_fwd_core(q, k, v, *, causal, q_offset, kv_block):
    """q: (B, Sq, H, hd); k, v: (B, Skv, H, hd) (heads already expanded).
    Returns out (B, Sq, H, hd_v) and lse (B, H, Sq), scanning KV blocks."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    hd_v = v.shape[-1]
    kv_block = min(kv_block, skv)
    assert skv % kv_block == 0
    nkv = skv // kv_block
    scale = 1.0 / (hd**0.5)
    kb = k.reshape(b, nkv, kv_block, h, hd).swapaxes(0, 1)
    vb = v.reshape(b, nkv, kv_block, h, hd_v).swapaxes(0, 1)

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd_v), jnp.float32)

    def kv_step(carry, inp):
        m, l, acc = carry
        k_j, v_j, jk = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k_j).astype(jnp.float32) * scale
        if causal:
            qpos = q_offset + jnp.arange(sq)
            kpos = jk * kv_block + jnp.arange(kv_block)
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(v_j.dtype), v_j).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nkv)))
    out = (acc / jnp.maximum(l, 1e-30)[..., None])
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return out.swapaxes(1, 2).astype(q.dtype), lse  # (B, Sq, H, hd_v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_attention(q, k, v, causal, q_offset, kv_block):
    out, _ = _flash_fwd_core(q, k, v, causal=causal, q_offset=q_offset,
                             kv_block=kv_block)
    return out


def _flash_fwd(q, k, v, causal, q_offset, kv_block):
    out, lse = _flash_fwd_core(q, k, v, causal=causal, q_offset=q_offset,
                               kv_block=kv_block)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_offset, kv_block, res, g):
    q, k, v, out, lse = res
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    hd_v = v.shape[-1]
    kv_block = min(kv_block, skv)
    nkv = skv // kv_block
    scale = 1.0 / (hd**0.5)
    kb = k.reshape(b, nkv, kv_block, h, hd).swapaxes(0, 1)
    vb = v.reshape(b, nkv, kv_block, h, hd_v).swapaxes(0, 1)
    gf = g.astype(jnp.float32)
    # delta_i = sum_d dO_i . O_i  (B, H, Sq)
    delta = jnp.einsum("bqhd,bqhd->bhq", gf, out.astype(jnp.float32))

    def kv_step(dq, inp):
        k_j, v_j, jk = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k_j).astype(jnp.float32) * scale
        if causal:
            qpos = q_offset + jnp.arange(sq)
            kpos = jk * kv_block + jnp.arange(kv_block)
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                  # (B, H, Sq, kvb)
        dv_j = jnp.einsum("bhqk,bqhd->bkhd", p, gf)
        dp = jnp.einsum("bqhd,bkhd->bhqk", gf,
                        v_j.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhqk,bkhd->bqhd", ds,
                             k_j.astype(jnp.float32))
        dk_j = jnp.einsum("bhqk,bqhd->bkhd", ds, q.astype(jnp.float32))
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((b, sq, h, hd), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(kv_step, dq0, (kb, vb, jnp.arange(nkv)))
    dk = dk_b.swapaxes(0, 1).reshape(b, skv, h, hd)
    dv = dv_b.swapaxes(0, 1).reshape(b, skv, h, hd_v)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
) -> jax.Array:
    """Online-softmax attention. q: (B, Sq, H, hd); k,v: (B, Skv, KV, hd).

    ``q_offset``: global position of q[0] relative to k[0] (sequence-parallel
    shards pass their shard offset). fp32 accumulators; bf16 GEMMs.

    The query axis is processed whole (q-blocking under a sharded sequence
    axis only causes GSPMD resharding; ``q_block`` is kept for API compat and
    ignored) and KV is scanned in ``kv_block`` chunks through the
    flash-attention custom VJP above.
    """
    h = q.shape[2]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    return _flash_attention(q, k, v, causal, int(q_offset), kv_block)


def _swa_logits_mask(start, window, q_block, span):
    qpos = start + jnp.arange(q_block)              # padded coords
    kpos = start - window + jnp.arange(span)        # global kv coord
    return ((kpos[None, :] <= qpos[:, None])
            & (kpos[None, :] > qpos[:, None] - window - 1)
            & (kpos[None, :] >= 0))


def _swa_fwd_core(q, kp, vp, *, window, q_offset, q_block):
    """q: (B, Sq, H, hd); kp/vp: left-padded (B, w+Skv, H, hd).
    Returns out and lse (B, H, Sq)."""
    b, sq, h, hd = q.shape
    nq = sq // q_block
    span = window + q_block
    scale = 1.0 / (hd**0.5)
    qb_ = q.reshape(b, nq, q_block, h, hd)

    def q_step(_, qi):
        q_i, iq = qi
        start = q_offset + iq * q_block
        k_i = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        v_i = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_i).astype(jnp.float32) * scale
        s = jnp.where(_swa_logits_mask(start, window, q_block, span), s,
                      NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_i.dtype), v_i)
        out = out / l[..., None].astype(out.dtype)
        lse = m[..., 0] + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.astype(q.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None,
                                   (qb_.swapaxes(0, 1), jnp.arange(nq)))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, hd)
    lse = lses.transpose(1, 2, 0, 3).reshape(b, h, sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _swa_attention(q, kp, vp, window, q_offset, q_block):
    out, _ = _swa_fwd_core(q, kp, vp, window=window, q_offset=q_offset,
                           q_block=q_block)
    return out


def _swa_fwd(q, kp, vp, window, q_offset, q_block):
    out, lse = _swa_fwd_core(q, kp, vp, window=window, q_offset=q_offset,
                             q_block=q_block)
    return out, (q, kp, vp, out, lse)


def _swa_bwd(window, q_offset, q_block, res, g):
    """Flash-style backward for the windowed path: recompute P per q block;
    dK/dV accumulate into the padded buffers with read-modify-write slices
    (adjacent spans overlap by `window`)."""
    q, kp, vp, out, lse = res
    b, sq, h, hd = q.shape
    nq = sq // q_block
    span = window + q_block
    scale = 1.0 / (hd**0.5)
    gf = g.astype(jnp.float32)
    delta = jnp.einsum("bqhd,bqhd->bhq", gf, out.astype(jnp.float32))
    qb_ = q.reshape(b, nq, q_block, h, hd).swapaxes(0, 1)
    gb_ = gf.reshape(b, nq, q_block, h, hd).swapaxes(0, 1)
    lse_b = lse.reshape(b, h, nq, q_block).transpose(2, 0, 1, 3)
    dl_b = delta.reshape(b, h, nq, q_block).transpose(2, 0, 1, 3)

    dkp0 = jnp.zeros(kp.shape, jnp.float32)
    dvp0 = jnp.zeros(vp.shape, jnp.float32)

    def q_step(carry, inp):
        dkp, dvp = carry
        q_i, g_i, lse_i, dl_i, iq = inp
        start = q_offset + iq * q_block
        k_i = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        v_i = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_i).astype(jnp.float32) * scale
        s = jnp.where(_swa_logits_mask(start, window, q_block, span), s,
                      NEG_INF)
        p = jnp.exp(s - lse_i[..., None])
        dv_i = jnp.einsum("bhqk,bqhd->bkhd", p, g_i)
        dp = jnp.einsum("bqhd,bkhd->bhqk", g_i, v_i.astype(jnp.float32))
        ds = p * (dp - dl_i[..., None]) * scale
        dq_i = jnp.einsum("bhqk,bkhd->bqhd", ds, k_i.astype(jnp.float32))
        dk_i = jnp.einsum("bhqk,bqhd->bkhd", ds, q_i.astype(jnp.float32))
        # read-modify-write the overlapping span
        dkp = jax.lax.dynamic_update_slice_in_dim(
            dkp, jax.lax.dynamic_slice_in_dim(dkp, start, span, 1) + dk_i,
            start, axis=1)
        dvp = jax.lax.dynamic_update_slice_in_dim(
            dvp, jax.lax.dynamic_slice_in_dim(dvp, start, span, 1) + dv_i,
            start, axis=1)
        return (dkp, dvp), dq_i

    (dkp, dvp), dq_b = jax.lax.scan(
        q_step, (dkp0, dvp0), (qb_, gb_, lse_b, dl_b, jnp.arange(nq)))
    dq = dq_b.swapaxes(0, 1).reshape(b, sq, h, hd)
    return (dq.astype(q.dtype), dkp.astype(kp.dtype), dvp.astype(vp.dtype))


_swa_attention.defvjp(_swa_fwd, _swa_bwd)


def sliding_window_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    q_offset: jax.Array | int = 0,
    q_block: int = 512,
) -> jax.Array:
    """Causal attention where each token sees at most `window` predecessors.

    Sub-quadratic: q block i dynamic-slices KV rows
    [i*qb + q_offset - window, i*qb + q_offset + qb) — compiled FLOPs are
    O(Sq * (window + q_block)). Flash-style custom VJP: only (q, k, v, out,
    lse) are saved across the remat boundary (no per-block P residuals).
    """
    b, sq, h, hd = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    q_block = min(q_block, sq)
    assert sq % q_block == 0
    # Left-pad KV by `window` so every slice is in range; grads of the pad
    # rows are discarded by the pad op's own VJP.
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    return _swa_attention(q, kp, vp, window, int(q_offset), q_block)


# ---------------------------------------------------------------------------
# Decode-time attention (1 new token vs KV cache).
# ---------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    window: int | None = None,
) -> jax.Array:
    """q: (B, 1, H, hd); caches: (B, S, KV, hd); cache_len: (B,) valid lengths.

    Full-cache dot product with length (and optional window) masking; fp32
    softmax. Sequence-sharded caches compose with GSPMD partial softmax.
    """
    b, _, h, hd = q.shape
    s = k_cache.shape[1]
    k = _expand_kv(k_cache, h)
    v = _expand_kv(v_cache, h)
    scale = 1.0 / (hd**0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    pos = jnp.arange(s)
    valid = pos[None, :] < cache_len[:, None]  # (B, S)
    if window is not None:
        valid &= pos[None, :] >= (cache_len[:, None] - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    out = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v.dtype), v)
    out = out / jnp.sum(p, axis=-1)[..., None].astype(out.dtype)
    return out.swapaxes(1, 2).astype(q.dtype)  # (B, 1, H, hd)
