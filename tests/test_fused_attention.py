"""Fused flash-attention kernel: forward + gradient parity vs the
scores-materialized oracle, in both 4D and 5D forms, through a full
evoformer_block, and across dist modes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dist import GspmdDist, LocalDist
from repro.core.evoformer import (
    EvoformerConfig,
    evoformer_block,
    init_evoformer_block,
)
from repro.exec.plan import current_plan, preset, use_plan
from repro.kernels import ops, ref

ATOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


def _mk(n, sq, skv, h, d, dtype, with_bias, with_mask, bias_b=1, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (n, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (n, skv, h, d), dtype)
    v = jax.random.normal(ks[2], (n, skv, h, d), dtype)
    bias = (jax.random.normal(ks[3], (bias_b, h, sq, skv), dtype)
            if with_bias else None)
    mask = None
    if with_mask:
        mask = jnp.where(jax.random.bernoulli(ks[4], 0.85, (n, skv)), 0.0,
                         -1e9).astype(jnp.float32)
    return q, k, v, bias, mask


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_bias,with_mask", [
    (True, True), (True, False), (False, True), (False, False),
])
def test_fused_attention_fwd_4d(dtype, with_bias, with_mask):
    n, sq, skv, h, d = 4, 33, 33, 2, 16
    q, k, v, bias, mask = _mk(n, sq, skv, h, d, dtype, with_bias, with_mask,
                              bias_b=2)
    scale = 1.0 / (d ** 0.5)
    got = ops.fused_attention(q, k, v, bias=bias, mask=mask, scale=scale)
    want, _ = ref.attention_ref(q, k, v, bias, mask, scale)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_attention_fwd_5d(dtype):
    b, g, s, h, d = 2, 5, 12, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (b, g, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, g, s, h, d), dtype)
    v = jax.random.normal(ks[2], (b, g, s, h, d), dtype)
    bias = jax.random.normal(ks[3], (b, h, s, s), dtype)
    mask = jnp.where(jax.random.bernoulli(ks[4], 0.8, (b, g, s)), 0.0,
                     -1e9).astype(jnp.float32)
    got = ops.fused_attention(q, k, v, bias=bias, mask=mask)
    assert got.shape == q.shape
    want, _ = ref.attention_ref(
        q.reshape(b * g, s, h, d), k.reshape(b * g, s, h, d),
        v.reshape(b * g, s, h, d), bias, mask.reshape(b * g, s),
        1.0 / (d ** 0.5))
    np.testing.assert_allclose(
        np.asarray(got, np.float32).reshape(b * g, s, h, d),
        np.asarray(want, np.float32), atol=ATOL[dtype], rtol=1e-2)


@pytest.mark.parametrize("with_bias,with_mask", [(True, True), (False, False)])
def test_fused_attention_grad_parity(with_bias, with_mask):
    """jax.grad through the custom recompute VJP == autodiff of the oracle."""
    n, sq, skv, h, d = 3, 17, 23, 2, 8
    q, k, v, bias, mask = _mk(n, sq, skv, h, d, jnp.float32, with_bias,
                              with_mask, bias_b=3, seed=2)
    scale = 0.5
    args = [a for a in (q, k, v, bias, mask) if a is not None]
    nargs = len(args)

    def f1(*a):
        b_ = a[3] if with_bias else None
        m_ = a[-1] if with_mask else None
        return jnp.sum(jnp.sin(ops.fused_attention(
            a[0], a[1], a[2], bias=b_, mask=m_, scale=scale)))

    def f2(*a):
        b_ = a[3] if with_bias else None
        m_ = a[-1] if with_mask else None
        return jnp.sum(jnp.sin(ref.attention_ref(
            a[0], a[1], a[2], b_, m_, scale)[0]))

    g1 = jax.grad(f1, argnums=tuple(range(nargs)))(*args)
    g2 = jax.grad(f2, argnums=tuple(range(nargs)))(*args)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_fused_attention_kv_tile_invariance():
    """The KV tile is a pure execution knob — results must not depend on it."""
    q, k, v, bias, mask = _mk(2, 40, 40, 2, 16, jnp.float32, True, True)
    outs = [ops.fused_attention(q, k, v, bias=bias, mask=mask, kv_tile=t)
            for t in (0, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-6)


def test_fused_attention_xla_leg_matches_pallas_interpret(monkeypatch):
    """The XLA-native online-softmax forward (default off-TPU leg) and the
    Pallas kernel (REPRO_PALLAS_INTERPRET=1 validation leg) are the same
    computation."""
    q, k, v, bias, mask = _mk(3, 21, 29, 2, 16, jnp.float32, True, True,
                              bias_b=3, seed=5)
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    y_xla = ops.fused_attention(q, k, v, bias=bias, mask=mask, kv_tile=16)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    y_pallas = ops.fused_attention(q, k, v, bias=bias, mask=mask, kv_tile=16)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pallas),
                               atol=1e-6)


@pytest.mark.parametrize("with_bias,with_mask", [
    (True, True), (True, False), (False, True), (False, False),
])
def test_fused_pallas_backward_matches_ref(monkeypatch, with_bias, with_mask):
    """flash_attention_bwd_pallas (interpret mode) == autodiff of the
    scores-materialized oracle, for every bias/mask combination — including
    the bias-group (rep > 1) reduction sweep."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    n, sq, skv, h, d = 4, 19, 27, 2, 8
    q, k, v, bias, mask = _mk(n, sq, skv, h, d, jnp.float32, with_bias,
                              with_mask, bias_b=2, seed=7)
    scale = 0.7
    args = [q, k, v] + ([bias] if with_bias else []) \
        + ([mask] if with_mask else [])

    def loss(*a):
        b_ = a[3] if with_bias else None
        m_ = a[3 + with_bias] if with_mask else None
        return jnp.sum(jnp.sin(ops.fused_attention(
            a[0], a[1], a[2], bias=b_, mask=m_, scale=scale, kv_tile=16)))

    got = jax.grad(loss, argnums=tuple(range(len(args))))(*args)
    out, _ = ref.attention_ref(q, k, v, bias if with_bias else None,
                               mask if with_mask else None, scale)
    want = ref.attention_bwd_ref(q, k, v, bias if with_bias else None,
                                 mask if with_mask else None,
                                 jnp.cos(out), scale)
    want = [w for w in want if w is not None]
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                                   rtol=1e-3)


def test_fused_pallas_backward_mesh_local_bias_two_sweeps(monkeypatch):
    """rep == 1 (bias batch == N, the mesh-local bias-group case): dbias is
    emitted from the dq sweep (two recompute sweeps instead of three) and
    must still match the autodiff oracle."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    n, sq, skv, h, d = 3, 19, 27, 2, 8
    q, k, v, bias, mask = _mk(n, sq, skv, h, d, jnp.float32, True, True,
                              bias_b=n, seed=11)
    scale = 0.6

    def loss(q_, k_, v_, b_, m_):
        return jnp.sum(jnp.sin(ops.fused_attention(
            q_, k_, v_, bias=b_, mask=m_, scale=scale, kv_tile=16)))

    got = jax.grad(loss, argnums=(0, 1, 2, 3, 4))(q, k, v, bias, mask)
    out, _ = ref.attention_ref(q, k, v, bias, mask, scale)
    want = ref.attention_bwd_ref(q, k, v, bias, mask, jnp.cos(out), scale)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                                   rtol=1e-3)


def test_fused_pallas_backward_matches_scan_bf16(monkeypatch):
    """bf16: the Pallas backward and the jnp KV-scan backward agree on the
    same residuals (the scan is the oracle leg of ops._attn_bwd)."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    q, k, v, bias, mask = _mk(2, 24, 24, 2, 16, jnp.bfloat16, True, True,
                              bias_b=2, seed=9)

    def loss(q_, k_, v_):
        return jnp.sum(ops.fused_attention(
            q_, k_, v_, bias=bias, mask=mask, kv_tile=16).astype(jnp.float32)
            ** 2)

    g_pallas = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    # Pin the scan backward via a plan scope (the old FORCE_SCAN_ATTN_BWD
    # module global): the leg bakes into the op call's trace, so scoping the
    # grad call is sufficient and nothing leaks to other tests.
    with use_plan(current_plan().with_kernels(attn_bwd="scan")):
        g_scan = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_pallas, g_scan):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        scale = max(1.0, float(np.abs(b).max()))
        assert float(np.abs(a - b).max()) <= 2e-2 * scale


def test_fused_attention_disabled_matches_kernel():
    """The 'oracle' plan's fallback == the kernel path (A/B as a use_plan
    scope instead of the old KERNELS_ENABLED mutation)."""
    q, k, v, bias, mask = _mk(2, 16, 16, 2, 8, jnp.float32, True, True)
    y_kern = ops.fused_attention(q, k, v, bias=bias, mask=mask)
    with use_plan(preset("oracle")):
        y_ref = ops.fused_attention(q, k, v, bias=bias, mask=mask)
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_ref),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# Through a full evoformer_block (acceptance criterion) and across dist modes.
# ---------------------------------------------------------------------------

CFG = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2,
                      head_dim=8, opm_dim=8, tri_mult_dim=16, n_blocks=2)


@pytest.fixture
def block_inputs():
    B, s, r = 2, 6, 10
    msa = jax.random.normal(jax.random.PRNGKey(1), (B, s, r, CFG.d_msa))
    pair = jax.random.normal(jax.random.PRNGKey(2), (B, r, r, CFG.d_pair))
    return (msa, pair, jnp.ones((B, s, r)), jnp.ones((B, r)),
            jnp.ones((B, r, r)))


def _block_grads(params, inputs, cfg, dist):
    def loss(p):
        m, z = evoformer_block(p, *inputs, dist=dist, cfg=cfg)
        return jnp.sum(m ** 2) + jnp.sum(z ** 2)

    return jax.grad(loss)(params)


def test_evoformer_block_grad_parity_fused_vs_oracle(block_inputs):
    """Gradient parity between the fused-attention block and the
    scores-materialized oracle block, under jax.grad through the whole
    evoformer_block (fp32: 1e-5)."""
    params = init_evoformer_block(jax.random.PRNGKey(0), CFG)
    g_fused = _block_grads(params, block_inputs, CFG, LocalDist())
    with use_plan(preset("oracle")):
        g_ref = _block_grads(params, block_inputs, CFG, LocalDist())
    flat1, tree1 = jax.tree.flatten(g_fused)
    flat2, tree2 = jax.tree.flatten(g_ref)
    assert tree1 == tree2
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-4)


@pytest.mark.parametrize("mode", ["local", "gspmd"])
def test_evoformer_block_fused_dist_modes(block_inputs, mode):
    """Fused path under LocalDist and GspmdDist (1-device mesh) agrees with
    the LocalDist oracle; the ShardMapDist mode runs in
    test_distributed.py subprocesses with real device counts."""
    params = init_evoformer_block(jax.random.PRNGKey(0), CFG)
    m_ref, z_ref = evoformer_block(params, *block_inputs, dist=LocalDist(),
                                   cfg=CFG)
    if mode == "local":
        dist = LocalDist()
    else:
        from repro.launch.mesh import make_host_mesh

        dist = GspmdDist(mesh=make_host_mesh(model=1, data=1), axis="model")
    with_jit = jax.jit(lambda p, *a: evoformer_block(p, *a, dist=dist,
                                                     cfg=CFG))
    m, z = with_jit(params, *block_inputs)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), atol=2e-5)


def test_evoformer_block_bf16_grad_parity(block_inputs):
    """bf16 parity between fused and oracle paths within 2e-2."""
    params = init_evoformer_block(jax.random.PRNGKey(0), CFG)
    cfg = dataclasses.replace(CFG, compute_dtype=jnp.bfloat16)
    inputs = tuple(x.astype(jnp.bfloat16) if x.ndim == 4 else x
                   for x in block_inputs)
    g_fused = _block_grads(params, inputs, cfg, LocalDist())
    with use_plan(preset("oracle")):
        g_ref = _block_grads(params, inputs, cfg, LocalDist())
    for a, b in zip(jax.tree.leaves(g_fused), jax.tree.leaves(g_ref)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        # Scale-normalized max-abs: 4e-2 relative to the gradient magnitude
        # (bf16 eps ~8e-3; absolute tolerances are unattainable for O(10)
        # grads). The fused pair-stack path keeps the triangle/OPM products
        # in fp32 while the materialized path rounds them to bf16, so the
        # A/B delta here is bf16 rounding noise, not a defect.
        scale = max(1.0, float(np.abs(b).max()))
        assert float(np.abs(a - b).max()) <= 4e-2 * scale
