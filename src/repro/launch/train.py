"""Training launcher CLI.

Single-host (real devices):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 50 --batch 4 --seq 64

On a real TPU pod slice this same entry point builds the production mesh and
pjit-shards per parallel/plan.py (the code path the dry-run certifies).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.data import lm_batches
from repro.exec.plan import PRESETS, preset, use_plan
from repro.layers.params import count_params
from repro.models.decoder import init_model, lm_loss
from repro.train.checkpoint import save_checkpoint
from repro.train.loop import instrument_train_step, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "lamb"])
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--plan", default="default", choices=sorted(PRESETS),
                    help="ExecutionPlan preset the run executes under")
    ap.add_argument("--trace", default=None, metavar="EVENTS.jsonl",
                    help="record obs train_step telemetry to this JSONL "
                         "file (inspect with `python -m repro.obs report`)")
    args = ap.parse_args()

    with use_plan(preset(args.plan)):
        if args.trace:
            from repro.obs import use_tracer

            with use_tracer() as tr:
                _run(args)
            n = tr.dump_jsonl(args.trace)
            print(f"wrote {args.trace} ({n} events)")
        else:
            _run(args)


def _run(args):
    cfg = get_config(args.arch, reduced_variant=args.reduced)
    params = init_model(jax.random.PRNGKey(0), cfg)
    print(f"{args.arch} ({'reduced' if args.reduced else 'full'}): "
          f"{count_params(params):,} params on {len(jax.devices())} device(s)")

    init_state, train_step = make_train_step(
        lambda p, b, r: lm_loss(p, b, cfg), optimizer=args.optimizer,
        base_lr=args.lr, warmup_steps=max(5, args.steps // 20),
        total_steps=args.steps, accum_steps=args.accum)
    state = init_state(params)
    step_fn = instrument_train_step(
        jax.jit(train_step), tokens_per_step=args.batch * args.seq)

    gen = lm_batches(vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=0)
    t0 = time.time()
    for i in range(args.steps):
        lb = next(gen)
        batch = {"tokens": jnp.asarray(lb.tokens),
                 "targets": jnp.asarray(lb.targets),
                 "mask": jnp.asarray(lb.mask)}
        if cfg.modality and cfg.modality.n_prefix_tokens:
            batch["prefix_embeds"] = jnp.zeros(
                (args.batch, cfg.modality.n_prefix_tokens, cfg.d_model),
                jnp.bfloat16)
        state, metrics = step_fn(state, batch, jax.random.PRNGKey(i))
        if (i + 1) % 10 == 0:
            print(f"step {i+1:4d} loss {float(metrics['loss']):.4f} "
                  f"ppl {float(metrics['ppl']):.1f} "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
    print(f"{args.steps} steps in {time.time()-t0:.1f}s")
    if args.ckpt_dir:
        print("saved:", save_checkpoint(args.ckpt_dir, args.steps, state))


if __name__ == "__main__":
    main()
