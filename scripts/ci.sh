#!/usr/bin/env bash
# Tier-1 CI: run the test suite twice — once with the Pallas kernels enabled
# (fused flash-attention / softmax / LN / elementwise paths) and once with
# REPRO_DISABLE_KERNELS=1 (pure-jnp oracle + scores-materialized attention).
# Any divergence between a kernel and its oracle fails fast in the first leg;
# the second leg proves the fallback/A-B path stays healthy on its own.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 leg 1/2: Pallas kernels ENABLED ==="
python -m pytest -x -q "$@"

echo "=== tier-1 leg 2/2: kernels DISABLED (REPRO_DISABLE_KERNELS=1, oracle paths) ==="
REPRO_DISABLE_KERNELS=1 python -m pytest -x -q "$@"

echo "ci.sh: both legs green"
