"""repro.analysis: repro-lint rules, compiled-program contracts, the runner.

Every rule and contract is proven BOTH ways: it fires on a deliberately-bad
fixture and stays quiet on the good twin (and on HEAD). The lint/contract
halves are pure (no jax); the integration tests drive the real runner and a
naive-shard merged-all-gather program in subprocesses, test_distributed
style."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.contracts import (
    CollectiveBudget,
    CompiledArtifact,
    NoInvoluntaryRemat,
    NoMergedAllGather,
    PeakBytesWithin,
    assert_no_merged_allgather,
    check_all,
    find_gather_then_slice,
    find_merged_allgathers,
)
from repro.analysis.lint import lint_source, lint_tree
from repro.roofline.analysis import count_collective_ops

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
REPRO = os.path.join(SRC, "repro")


def rules_of(src: str, relpath: str) -> list[str]:
    return [f.rule for f in lint_source(textwrap.dedent(src), relpath)]


# ---------------------------------------------------------------------------
# repro-lint rules: each fires on the bad fixture, not on the good twin
# ---------------------------------------------------------------------------


def test_r001_env_access_fires():
    assert rules_of("import os\nos.environ['X'] = '1'\n",
                    "core/foo.py") == ["R001"]
    assert rules_of("import os\nv = os.getenv('X')\n",
                    "serving/engine.py") == ["R001"]
    assert rules_of("import os as _o\n_o.environ.get('X')\n",
                    "core/foo.py") == ["R001"]


def test_r001_catches_aliased_from_import():
    # The cases the old ci.sh grep for "os.environ" missed entirely.
    assert "R001" in rules_of("from os import environ\n", "core/foo.py")
    assert "R001" in rules_of(
        "from os import getenv as ge\nv = ge('X')\n", "core/foo.py")


def test_r001_exempts_envcompat():
    src = "import os\nos.environ['XLA_FLAGS'] = 'x'\nos.getenv('Y')\n"
    assert rules_of(src, "exec/envcompat.py") == []
    assert rules_of(src, "exec/other.py") == ["R001", "R001"]


def test_r002_bare_except_fires():
    bad = """
    try:
        f()
    except Exception:
        pass
    """
    assert rules_of(bad, "serving/engine.py") == ["R002"]
    assert rules_of("try:\n    f()\nexcept:\n    pass\n",
                    "core/foo.py") == ["R002"]


def test_r002_allows_named_and_resilience():
    named = """
    try:
        f()
    except Exception as err:
        raise RuntimeError("x") from err
    """
    assert rules_of(named, "serving/engine.py") == []
    assert rules_of("try:\n    f()\nexcept Exception:\n    pass\n",
                    "resilience/inject.py") == []


def test_r003_wallclock_and_random_fire_in_traced_code():
    assert rules_of("import time\nt = time.time()\n",
                    "core/evoformer.py") == ["R003"]
    assert rules_of("import random\nx = random.random()\n",
                    "kernels/ops.py") == ["R003"]
    assert rules_of("import numpy as np\nx = np.random.normal()\n",
                    "memory/autochunk.py") == ["R003"]
    assert rules_of("import datetime\nt = datetime.datetime.now()\n",
                    "train/loop.py") == ["R003"]


def test_r003_scoped_to_traced_modules_and_allows_jax_random():
    # launch/resilience/benchmark code may read clocks and host RNGs.
    assert rules_of("import time\nt = time.time()\n",
                    "launch/dryrun.py") == []
    assert rules_of("import random\nrandom.seed(0)\n",
                    "resilience/inject.py") == []
    # jax.random is the sanctioned in-trace RNG.
    assert rules_of("import jax\nk = jax.random.split(key)\n",
                    "core/evoformer.py") == []


def test_r004_r005_scores_materialized_attention_fires():
    bad = """
    import jax
    import jax.numpy as jnp
    def attend(q, k, v):
        scores = jnp.einsum("bgihd,bgjhd->bghij", q, k)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bghij,bgjhd->bgihd", probs, v)
    """
    got = rules_of(bad, "core/evoformer.py")
    assert got == ["R004", "R005", "R004"], got
    # The same source outside the pair-stack modules is not in scope.
    assert rules_of(bad, "models/decoder.py") == []


def test_suppressions():
    line = ('import jax.numpy as jnp\n'
            'o = jnp.einsum("ij,jk->ik", a, b)'
            '  # repro-lint: disable=R004\n')
    assert rules_of(line, "core/evoformer.py") == []
    above = ('import jax.numpy as jnp\n'
             '# repro-lint: disable=R004 -- sanctioned fallback\n'
             'o = jnp.einsum("ij,jk->ik", a, b)\n')
    assert rules_of(above, "core/evoformer.py") == []
    multiline = ('import jax.numpy as jnp\n'
                 'o = jnp.einsum("ij,jk->ik", a,\n'
                 '               b)  # repro-lint: disable=R004\n')
    assert rules_of(multiline, "core/evoformer.py") == []
    whole_file = ('# repro-lint: disable-file=R004\n'
                  'import jax.numpy as jnp\n'
                  'o = jnp.einsum("ij,jk->ik", a, b)\n')
    assert rules_of(whole_file, "core/evoformer.py") == []
    # Suppressing a different rule does not silence this one.
    wrong = ('import jax.numpy as jnp\n'
             'o = jnp.einsum("ij,jk->ik", a, b)'
             '  # repro-lint: disable=R005\n')
    assert rules_of(wrong, "core/evoformer.py") == ["R004"]


def test_r006_print_and_stdout_fire_in_library_modules():
    assert rules_of("print('debug')\n", "serving/engine.py") == ["R006"]
    assert rules_of("import sys\nsys.stdout.write('x')\n",
                    "train/loop.py") == ["R006"]
    assert rules_of("import sys\nsys.stderr.writelines(['x'])\n",
                    "core/foo.py") == ["R006"]


def test_r006_quiet_twin_and_exempt_scopes():
    # Telemetry/report/CLI scopes may print; __main__ entrypoints too.
    for rel in ("obs/report.py", "obs/trace.py", "analysis/lint.py",
                "launch/serve.py", "analysis/__main__.py",
                "serving/__main__.py"):
        assert rules_of("print('report line')\n", rel) == []
    # The quiet twin: writes to ordinary file objects are not stdout.
    quiet = ("def dump(fh, log):\n"
             "    fh.write('x')\n"
             "    log.writelines(['x'])\n")
    assert rules_of(quiet, "serving/engine.py") == []


def test_r006_suppression():
    line = "print('sanctioned')  # repro-lint: disable=R006\n"
    assert rules_of(line, "serving/engine.py") == []
    wrong = "print('sanctioned')  # repro-lint: disable=R003\n"
    assert rules_of(wrong, "serving/engine.py") == ["R006"]


def test_lint_tree_clean_on_head():
    findings = lint_tree(REPRO)
    assert not findings, "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------------
# contracts: pure HLO finders on crafted artifacts
# ---------------------------------------------------------------------------

MERGED_AG_HLO = """
ENTRY %main (p0: f32[2,8,16,8]) -> f32[16,16,8] {
  %p0 = f32[2,8,16,8]{3,2,1,0} parameter(0)
  %r = f32[16,16,8]{2,1,0} reshape(%p0)
  %ag = f32[16,16,8]{2,1,0} all-gather(%r), dimensions={0}
  ROOT %out = f32[16,16,8]{2,1,0} add(%ag, %ag)
}
"""

CLEAN_AG_HLO = """
ENTRY %main (p0: f32[2,4,16,8]) -> f32[2,8,16,8] {
  %p0 = f32[2,4,16,8]{3,2,1,0} parameter(0)
  %ag = f32[2,8,16,8]{3,2,1,0} all-gather(%p0), dimensions={1}
  ROOT %out = f32[2,8,16,8]{3,2,1,0} add(%ag, %ag)
}
"""


def test_find_merged_allgathers():
    assert find_merged_allgathers(MERGED_AG_HLO, {16}, 3) == [[16, 16, 8]]
    assert find_merged_allgathers(CLEAN_AG_HLO, {16}, 3) == []
    # rank gate: a merged lead below min_rank does not count
    assert find_merged_allgathers(MERGED_AG_HLO, {16}, 4) == []
    # async form counts once, at the -start
    async_hlo = "%ag = f32[16,8,4]{2,1,0} all-gather-start(%x)\n"
    assert find_merged_allgathers(async_hlo, {16}, 3) == [[16, 8, 4]]
    with pytest.raises(AssertionError):
        assert_no_merged_allgather(MERGED_AG_HLO, {16}, 3)
    assert_no_merged_allgather(CLEAN_AG_HLO, {16}, 3)


GATHER_SLICE_HLO = """
ENTRY %main (p0: f32[2,4,8]) -> f32[2,4,8] {
  %p0 = f32[2,4,8]{2,1,0} parameter(0)
  %ag = f32[2,8,8]{2,1,0} all-gather(%p0), dimensions={1}
  %idx = s32[] partition-id()
  ROOT %ds = f32[2,4,8]{2,1,0} dynamic-slice(%ag, %idx), dynamic_slice_sizes={2,4,8}
}
"""


def test_find_gather_then_slice():
    pairs = find_gather_then_slice(GATHER_SLICE_HLO)
    assert len(pairs) == 1 and pairs[0][0] == "ag"
    # a gather consumed by compute (not a slice) is fine
    assert find_gather_then_slice(CLEAN_AG_HLO) == []
    # computation boundaries reset the gathered set
    split = GATHER_SLICE_HLO.replace("%idx", "}\n%idx")
    assert find_gather_then_slice(split) == []


def test_count_collective_ops_static():
    hlo = """
  %a = f32[4,4]{1,0} all-gather(%x), dimensions={0}
  %b = f32[4,4]{1,0} all-reduce(%y), to_apply=%sum
  %c = (f32[4,4], f32[4,4]) all-gather-start(%z)
  %d = f32[4,4]{1,0} all-gather-done(%c)
  %e = f32[4,4]{1,0} all-to-all(%w)
"""
    counts = count_collective_ops(hlo)
    # -start counts once; -done re-states the same gather, not a new one
    assert counts == {"all-gather": 2, "all-reduce": 1, "all-to-all": 1}


def test_contract_objects():
    art = CompiledArtifact("cell/x", MERGED_AG_HLO, peak_bytes=1000)
    v = check_all([NoMergedAllGather(frozenset({16}), 3)], art)
    assert len(v) == 1 and v[0].contract == "NoMergedAllGather"
    assert "cell/x" in v[0].render()

    assert NoInvoluntaryRemat().check(
        CompiledArtifact("c", GATHER_SLICE_HLO))
    assert not NoInvoluntaryRemat().check(
        CompiledArtifact("c", CLEAN_AG_HLO))

    budget = CollectiveBudget(max_per_block=1)
    assert not budget.check(CompiledArtifact("c", CLEAN_AG_HLO))
    over = CompiledArtifact("c", collective_counts={"all-gather": 5})
    assert budget.check(over)
    assert not CollectiveBudget(max_per_block=3, blocks=2).check(over)


def test_peak_bytes_within_two_sided():
    ok = CompiledArtifact("c", peak_bytes=1500)
    assert not PeakBytesWithin(modeled_bytes=1000, factor=2.0).check(ok)
    # compiled way above modeled: the model is lying low (over-admission)
    high = CompiledArtifact("c", peak_bytes=5000)
    assert PeakBytesWithin(1000, 2.0).check(high)
    # compiled way below modeled: the model cries wolf (over-serialization)
    low = CompiledArtifact("c", peak_bytes=100)
    assert PeakBytesWithin(1000, 2.0).check(low)
    # a backend with no memory_analysis is itself a violation
    assert PeakBytesWithin(1000, 2.0).check(
        CompiledArtifact("c", peak_bytes=None))


# ---------------------------------------------------------------------------
# integration: the runner + a real naive-shard program, in subprocesses
# ---------------------------------------------------------------------------


def run_sub(argv, devices=None, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    if devices:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run([sys.executable, *argv], env=env, cwd=cwd,
                          capture_output=True, text=True, timeout=900)


def test_runner_lint_clean_on_head():
    out = run_sub(["-m", "repro.analysis", "--lint-only"])
    assert out.returncode == 0, out.stdout + out.stderr
    assert "repro-lint: clean" in out.stdout


def test_runner_fails_on_bad_tree(tmp_path):
    (tmp_path / "core").mkdir()
    (tmp_path / "core" / "bad.py").write_text(textwrap.dedent("""
        import os, time
        FLAG = os.environ.get("REPRO_X")
        def traced():
            t = time.time()
            try:
                return t
            except Exception:
                pass
    """))
    out = run_sub(["-m", "repro.analysis", "--lint-only",
                   "--lint-root", str(tmp_path)])
    assert out.returncode == 1, out.stdout + out.stderr
    for rule in ("R001", "R002", "R003"):
        assert rule in out.stdout, (rule, out.stdout)


NAIVE_SHARD_SCRIPT = r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis.contracts import find_merged_allgathers
from repro.launch.mesh import _mesh

B, G, S, D = 2, 8, 16, 8
mesh = _mesh((1, 2), ("data", "model"))
# The pre-PR-2 bug shape: the (B, G) pair already flattened into one merged
# lead of B*G=16, sharded across the model axis. Any consumer that needs
# the full representation forces GSPMD to all-gather the merged dim whole.
x = jax.random.normal(jax.random.PRNGKey(0), (B * G, S, D))

def naive(x):
    x = jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P("model", None, None)))
    y = jax.lax.with_sharding_constraint(
        x * 2.0, NamedSharding(mesh, P(None, None, None)))
    return y + 1.0

with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
    hlo = jax.jit(naive).lower(x).compile().as_text()
bad = find_merged_allgathers(hlo, {B * G}, min_rank=3)
assert bad, "expected the naive flatten-then-shard to force a merged-lead " \
    "all-gather, found none:\n" + hlo
print("NAIVE_SHARD_CONTRACT_FIRES", bad[0])
"""


def test_merged_allgather_contract_fires_on_naive_shard():
    """The NoMergedAllGather finder catches a real compiled program that
    merges a mesh-sharded group dim — the exact regression the contract
    guards, rebuilt via a naive flatten on a 2-device host mesh."""
    out = run_sub(["-c", NAIVE_SHARD_SCRIPT], devices=2)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "NAIVE_SHARD_CONTRACT_FIRES" in out.stdout


def test_runner_contract_cell_clean_on_head(tmp_path):
    """One real contract cell end-to-end through `python -m repro.analysis`
    (ci.sh leg 7 runs the full matrix; this keeps tier-1 to a single
    compile). A filtered run must not touch the checked-in baseline."""
    out = run_sub(["-m", "repro.analysis", "--contracts-only",
                   "--presets", "default", "--cells", "evoformer_fwd",
                   "--devices", "2"], cwd=str(tmp_path))
    assert out.returncode == 0, out.stdout + out.stderr
    assert "contract evoformer_fwd/default: ok" in out.stdout
    assert not (tmp_path / "BENCH_contracts.json").exists()


def test_bench_contracts_baseline_in_sync():
    """The checked-in BENCH_contracts.json matches what the runner would
    write: full default+oracle matrix, every cell contract-clean, ratios
    recorded."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_contracts.json")
    with open(path) as fh:
        payload = json.load(fh)
    assert payload["presets"] == ["default", "oracle"]
    cells = payload["cells"]
    names = {row["cell"] for row in cells}
    for cell in ("evoformer_fwd", "evoformer_grad", "triangle_opm",
                 "alphafold_dryrun", "dap_stack", "dap_jaxpr"):
        for pname in ("default", "oracle"):
            assert f"{cell}/{pname}" in names, (cell, pname)
    for row in cells:
        assert row["violations"] == [], row
        if row["modeled_bytes"] and row["compiled_peak_bytes"]:
            assert row["ratio"] > 0
