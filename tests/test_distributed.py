"""Distributed-equivalence tests (paper-faithful DAP + TP baseline).

These run in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count
set *before* jax import, keeping the main test process at 1 device.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(script: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


DAP_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, evoformer_stack
from repro.core.dap import dap_evoformer_stack, shard_dap_inputs
cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2, head_dim=8,
                      opm_dim=8, tri_mult_dim=16, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B,s,r = 2,8,12
msa = jax.random.normal(jax.random.PRNGKey(1),(B,s,r,cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2),(B,r,r,cfg.d_pair))
masks = (jnp.ones((B,s,r)), jnp.ones((B,r)), jnp.ones((B,r,r)))
m_ref, p_ref = evoformer_stack(params, msa, pair, *masks, cfg=cfg, remat=False)
from repro.launch.mesh import _mesh
mesh = _mesh((1,4), ("data","model"))
fn = jax.jit(dap_evoformer_stack(mesh, cfg, remat=False))
args = shard_dap_inputs(mesh, msa, pair, *masks)
m_dap, p_dap = fn(params, *args)
np.testing.assert_allclose(np.asarray(m_dap), np.asarray(m_ref), atol=3e-5)
np.testing.assert_allclose(np.asarray(p_dap), np.asarray(p_ref), atol=3e-5)
import re
txt = fn.lower(params, *args).compile().as_text()
n_a2a = len(re.findall(r"all-to-all", txt))
n_ag = len(re.findall(r"all-gather", txt))
assert n_a2a > 0 and n_ag > 0, (n_a2a, n_ag)
print("DAP_OK", n_a2a, n_ag)
"""


TP_SCRIPT = r"""
import re, numpy as np, jax, jax.numpy as jnp
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, evoformer_stack
from repro.core.tp import tp_evoformer_stack
cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2, head_dim=8,
                      opm_dim=8, tri_mult_dim=16, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B,s,r = 2,6,10
msa = jax.random.normal(jax.random.PRNGKey(1),(B,s,r,cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2),(B,r,r,cfg.d_pair))
masks = (jnp.ones((B,s,r)), jnp.ones((B,r)), jnp.ones((B,r,r)))
m_ref, p_ref = evoformer_stack(params, msa, pair, *masks, cfg=cfg, remat=False)
from repro.launch.mesh import _mesh
mesh = _mesh((1,2), ("data","model"))
fn = jax.jit(tp_evoformer_stack(mesh, cfg, remat=False))
m_tp, p_tp = fn(params, msa, pair, *masks)
np.testing.assert_allclose(np.asarray(m_tp), np.asarray(m_ref), atol=3e-5)
np.testing.assert_allclose(np.asarray(p_tp), np.asarray(p_ref), atol=3e-5)
txt = fn.lower(params, msa, pair, *masks).compile().as_text()
# count all-reduce OPS (result definitions), not name mentions — newer XLA
# text repeats the op name on operand references.
n_ar = len(re.findall(r"= \S+ all-reduce\(", txt)) or \
    len(re.findall(r"all-reduce", txt))
# paper Table III: 6 AllReduce in the forward pass per block
assert n_ar == 6, n_ar
print("TP_OK", n_ar)
"""


LM_GSPMD_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.decoder import init_model, lm_loss
cfg = get_config("qwen2-1.5b", reduced_variant=True)
params = init_model(jax.random.PRNGKey(0), cfg)
B, S = 4, 32
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
batch = {"tokens": toks, "targets": toks, "mask": jnp.ones((B, S))}
loss_ref, _ = lm_loss(params, batch, cfg)
from repro.launch.mesh import _mesh
mesh = _mesh((2, 2), ("data", "model"))
def shard_x(x):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P("data", "model", None)))
with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
    loss_sharded, _ = jax.jit(
        lambda p, b: lm_loss(p, b, cfg, shard_x=shard_x))(params, batch)
np.testing.assert_allclose(float(loss_sharded), float(loss_ref), rtol=1e-4)
print("GSPMD_LM_OK", float(loss_sharded))
"""


MINI_DRYRUN_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.configs import get_config, INPUT_SHAPES
import repro.launch.dryrun as dr
import dataclasses
from repro.launch.mesh import _mesh
mesh = _mesh((2, 4), ("data", "model"))
cfg = get_config("qwen2-1.5b", reduced_variant=True)
shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=64, global_batch=4)
fn, args, in_sh, out_sh = dr.build_train(cfg, shape, mesh)
with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
    compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
mem = compiled.memory_analysis()
assert mem is not None
from repro.roofline import analysis
flops, bts = analysis.hlo_cost(compiled.as_text())
assert flops > 0 and bts > 0
print("MINI_DRYRUN_OK", flops > 0)
"""


@pytest.mark.slow
def test_dap_shard_map_equals_local_oracle():
    assert "DAP_OK" in run_sub(DAP_SCRIPT, devices=4)


@pytest.mark.slow
def test_tp_equals_local_oracle_and_allreduce_count():
    assert "TP_OK 6" in run_sub(TP_SCRIPT, devices=2)


@pytest.mark.slow
def test_gspmd_lm_loss_matches_single_device():
    assert "GSPMD_LM_OK" in run_sub(LM_GSPMD_SCRIPT, devices=4)


@pytest.mark.slow
def test_mini_dryrun_compiles_and_analyzes():
    assert "MINI_DRYRUN_OK" in run_sub(MINI_DRYRUN_SCRIPT, devices=8)
