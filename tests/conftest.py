import os
import sys

# NOTE: no --xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device. Multi-device tests spawn subprocesses that set
# the flag before importing jax (see test_distributed.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
