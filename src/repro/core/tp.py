"""Tensor-Parallel Evoformer — the paper's baseline (§IV.B.1, Table III).

Megatron-style column/row parallelism applied to Evoformer, exactly as the
paper describes for its comparison: QKV+gate projections column-parallel
(heads split across the `model` axis), output projection row-parallel with an
AllReduce; transitions column/row-parallel with an AllReduce. Outer-Product-
Mean and the Triangular Updates are NOT parallelizable under TP (paper Table
III) and run replicated.

Uses the *same parameter pytree* as the DAP/local Evoformer, slicing weights
per device inside shard_map — so the comparison is apples-to-apples, and the
equivalence test (TP output == local output) certifies correctness.

Scaling limit reproduced: the pair stack has 4 heads, so TP cannot exceed 4
devices there (the paper's core argument for DAP).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import evoformer as evo
from repro.core.dist import LocalDist, batch_spec, named_axis_size
from repro.kernels import ops
from repro.layers.attention import evoformer_attention
from repro.layers.norms import layer_norm
from repro.layers.params import dense

NEG_INF = -1e9


def _slice_cols(w, idx, n, groups: int = 1):
    """Column-slice a (d_in, groups*h*hd) weight into its per-device block,
    slicing each of `groups` equal segments (q|k|v merged layout)."""
    d_in, d_out = w.shape
    seg = d_out // groups
    loc = seg // n
    parts = [
        jax.lax.dynamic_slice_in_dim(w, g * seg + idx * loc, loc, axis=1)
        for g in range(groups)
    ]
    return jnp.concatenate(parts, axis=1)


def _slice_vec(b, idx, n, groups: int = 1):
    seg = b.shape[0] // groups
    loc = seg // n
    parts = [
        jax.lax.dynamic_slice_in_dim(b, g * seg + idx * loc, loc, axis=0)
        for g in range(groups)
    ]
    return jnp.concatenate(parts, axis=0)


def tp_gated_attention(p_attn, x_n, bias, key_mask, heads, head_dim, axis):
    """Column-parallel QKV/gate, row-parallel output + AllReduce."""
    idx = jax.lax.axis_index(axis)
    n = named_axis_size(axis)
    h_loc = heads // n
    dt = x_n.dtype

    wqkv = _slice_cols(p_attn["wqkv"]["w"], idx, n, groups=3).astype(dt)
    y = jnp.einsum("nsd,de->nse", x_n, wqkv)
    if "b" in p_attn["wqkv"]:
        y = y + _slice_vec(p_attn["wqkv"]["b"], idx, n, groups=3).astype(dt)
    q, k, v = jnp.split(y, 3, axis=-1)
    q = q.reshape(q.shape[:-1] + (h_loc, head_dim))
    k = k.reshape(k.shape[:-1] + (h_loc, head_dim))
    v = v.reshape(v.shape[:-1] + (h_loc, head_dim))

    bias_loc = None
    if bias is not None:  # (B, H, R, C) -> local heads
        bias_loc = jax.lax.dynamic_slice_in_dim(bias, idx * h_loc, h_loc, axis=1)
    mask = None
    if key_mask is not None:
        mask = jnp.where(key_mask > 0, 0.0, NEG_INF).astype(jnp.float32)
    ctx = evoformer_attention(q, k, v, bias=bias_loc, mask=mask)
    flat = ctx.reshape(ctx.shape[:-2] + (-1,))

    if "wg" in p_attn:
        wg = _slice_cols(p_attn["wg"]["w"], idx, n).astype(dt)
        g = jnp.einsum("nsd,de->nse", x_n, wg)
        flat = ops.bias_sigmoid_mul(g, _slice_vec(p_attn["wg"]["b"], idx, n), flat)

    wo_loc = jax.lax.dynamic_slice_in_dim(
        p_attn["wo"]["w"], idx * h_loc * head_dim, h_loc * head_dim, axis=0
    ).astype(dt)
    out = jnp.einsum("nse,eo->nso", flat, wo_loc)
    out = jax.lax.psum(out, axis)  # the TP AllReduce (paper Table III)
    if "b" in p_attn["wo"]:
        out = out + p_attn["wo"]["b"].astype(dt)
    return out


def tp_transition(p, x, axis):
    """Column-parallel first linear, row-parallel second + AllReduce."""
    idx = jax.lax.axis_index(axis)
    n = named_axis_size(axis)
    x_n = layer_norm(p["ln"], x)
    dt = x_n.dtype
    wi = _slice_cols(p["mlp"]["wi"]["w"], idx, n).astype(dt)
    bi = _slice_vec(p["mlp"]["wi"]["b"], idx, n).astype(dt)
    h = jax.nn.relu(jnp.einsum("...d,de->...e", x_n, wi) + bi)
    d_ff = p["mlp"]["wo"]["w"].shape[0]
    loc = d_ff // n
    wo = jax.lax.dynamic_slice_in_dim(p["mlp"]["wo"]["w"], idx * loc, loc,
                                      axis=0).astype(dt)
    out = jax.lax.psum(jnp.einsum("...e,eo->...o", h, wo), axis)
    return out + p["mlp"]["wo"]["b"].astype(dt)


def tp_evoformer_block(params, msa, pair, msa_mask, seq_mask, pair_mask, *,
                       cfg: evo.EvoformerConfig, axis="model"):
    """TP block: tensors replicated across `axis`, weights logically split."""
    b, s, r, _ = msa.shape
    local = LocalDist()

    # --- MSA row attention (TP over heads) ---
    p = params["msa_row"]
    z_n = layer_norm(p["ln_z"], pair)
    bias = dense(p["bias"], z_n).transpose(0, 3, 1, 2)  # (B, H, r, r)
    m_n = layer_norm(p["ln_m"], msa)
    x = m_n.reshape(b * s, r, cfg.d_msa)
    key_mask = jnp.broadcast_to(seq_mask[:, None, :], (b, s, r)).reshape(b * s, r)
    upd = tp_gated_attention(p["attn"], x, bias, key_mask, cfg.msa_heads,
                             cfg.head_dim, axis)
    msa = msa + upd.reshape(b, s, r, cfg.d_msa)

    # --- MSA column attention ---
    p = params["msa_col"]
    m_n = layer_norm(p["ln"], msa)
    x = m_n.transpose(0, 2, 1, 3).reshape(b * r, s, cfg.d_msa)
    key_mask = msa_mask.transpose(0, 2, 1).reshape(b * r, s)
    upd = tp_gated_attention(p["attn"], x, None, key_mask, cfg.msa_heads,
                             cfg.head_dim, axis)
    msa = msa + upd.reshape(b, r, s, cfg.d_msa).transpose(0, 2, 1, 3)

    msa = msa + tp_transition(params["msa_trans"], msa, axis)

    # --- OPM + triangular updates: NOT TP-parallelizable (replicated) ---
    pair = pair + evo.outer_product_mean(params["opm"], msa, msa_mask, local, cfg)
    pair = pair + evo.triangle_mult_outgoing(params["tri_mult_out"], pair,
                                             pair_mask, local, cfg)
    pair_t = pair.swapaxes(1, 2)
    pair_mask_t = pair_mask.swapaxes(1, 2)
    pair = pair + evo.triangle_mult_incoming(params["tri_mult_in"], pair,
                                             pair_t, pair_mask_t, local, cfg)

    # --- Triangular attentions (TP over the 4 pair heads) ---
    for name, transpose in (("tri_attn_start", False), ("tri_attn_end", True)):
        p = params[name]
        src = pair.swapaxes(1, 2) if transpose else pair
        z_n = layer_norm(p["ln"], src)
        bias = dense(p["bias"], z_n).transpose(0, 3, 1, 2)
        x = z_n.reshape(b * r, r, cfg.d_pair)
        key_mask = jnp.broadcast_to(seq_mask[:, None, :], (b, r, r)).reshape(b * r, r)
        upd = tp_gated_attention(p["attn"], x, bias, key_mask, cfg.pair_heads,
                                 cfg.head_dim, axis)
        upd = upd.reshape(b, r, r, cfg.d_pair)
        pair = pair + (upd.swapaxes(1, 2) if transpose else upd)

    pair = pair + tp_transition(params["pair_trans"], pair, axis)
    return msa, pair


def tp_evoformer_stack(mesh, cfg: evo.EvoformerConfig, *, remat: bool = True):
    """jit-able TP stack: activations replicated over 'model', batch over data
    axes. Scaling limit: model axis size must divide pair_heads (=4)."""
    bspec = P(batch_spec(mesh))

    def local_fn(params, msa, pair, msa_mask, seq_mask, pair_mask):
        def body(carry, p):
            m, z = carry
            m, z = tp_evoformer_block(p, m, z, msa_mask, seq_mask, pair_mask,
                                      cfg=cfg)
            return (m, z), None

        if remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        (m, z), _ = jax.lax.scan(body, (msa, pair), params)
        return m, z

    b4 = P(batch_spec(mesh), None, None, None)
    b3 = P(batch_spec(mesh), None, None)
    b2 = P(batch_spec(mesh), None)
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(), b4, b4, b3, b2, b3),
        out_specs=(b4, b4),
        check_rep=False,
    )
