"""AlphaFold training losses: masked-MSA, distogram, FAPE (+aux traj FAPE)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.structure import frames_from_3_points, frames_invert_apply

N_MSA_TOK = 23
N_DIST_BINS = 64


def masked_msa_loss(logits, true_msa, bert_mask):
    """logits (B, s, r, 23); true_msa int (B, s, r); bert_mask (B, s, r)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, true_msa[..., None], axis=-1)[..., 0]
    denom = jnp.sum(bert_mask) + 1e-6
    return -jnp.sum(ll * bert_mask) / denom


def distogram_loss(logits, pseudo_beta, seq_mask, min_d=2.3125, max_d=21.6875):
    """logits (B, r, r, 64); pseudo_beta (B, r, 3)."""
    d = jnp.linalg.norm(
        pseudo_beta[:, :, None] - pseudo_beta[:, None] + 1e-8, axis=-1
    )
    edges = jnp.linspace(min_d, max_d, N_DIST_BINS - 1)
    target = jnp.sum(d[..., None] > edges, axis=-1)  # (B, r, r) in [0, 63]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, target[..., None], axis=-1)[..., 0]
    mask2 = seq_mask[:, :, None] * seq_mask[:, None, :]
    return -jnp.sum(ll * mask2) / (jnp.sum(mask2) + 1e-6)


def true_frames_from_ca(coords):
    """Ground-truth frames from a CA trace via Gram-Schmidt on neighbours."""
    prev_ca = jnp.roll(coords, 1, axis=-2)
    next_ca = jnp.roll(coords, -1, axis=-2)
    return frames_from_3_points(prev_ca, coords, next_ca)


def fape(pred_rot, pred_trans, true_rot, true_trans, pred_pos, true_pos,
         seq_mask, clamp=10.0, scale=10.0):
    """Frame-Aligned Point Error (AlphaFold Alg. 28), CA-only variant.

    pred/true frames: (B, r, 3, 3), (B, r, 3); positions: (B, r, 3).
    """
    # Local coords of every position j in every frame i: (B, i, j, 3)
    p_local = _pairwise_local(pred_rot, pred_trans, pred_pos)
    t_local = _pairwise_local(true_rot, true_trans, true_pos)
    err = jnp.sqrt(jnp.sum(jnp.square(p_local - t_local), axis=-1) + 1e-8)
    err = jnp.minimum(err, clamp) / scale
    mask2 = seq_mask[:, :, None] * seq_mask[:, None, :]
    return jnp.sum(err * mask2) / (jnp.sum(mask2) + 1e-6)


def _pairwise_local(rot, trans, pos):
    """x_ij = R_i^{-1} (pos_j - t_i): (B, i, j, 3)."""
    rel = pos[:, None, :, :] - trans[:, :, None, :]
    return jnp.einsum("bixy,bijx->bijy", rot, rel)


def alphafold_loss(outputs, batch, *, w_fape=0.5, w_msa=2.0, w_dist=0.3,
                   w_aux=0.5):
    """outputs: dict from the model; batch: ProteinBatch-style dict."""
    seq_mask = batch["seq_mask"]
    true_rot, true_trans = true_frames_from_ca(batch["pseudo_beta"])
    rot, trans = outputs["frames"]
    l_fape = fape(rot, trans, true_rot, true_trans, trans, batch["pseudo_beta"],
                  seq_mask)
    # Aux: mean FAPE over the structure-module trajectory.
    traj_rot, traj_trans = outputs["traj"]

    def traj_fape(rt):
        r, t = rt
        return fape(r, t, true_rot, true_trans, t, batch["pseudo_beta"], seq_mask)

    l_aux = jnp.mean(jax.vmap(traj_fape)((traj_rot, traj_trans)))
    l_msa = masked_msa_loss(outputs["msa_logits"], batch["true_msa"],
                            batch["bert_mask"])
    l_dist = distogram_loss(outputs["distogram_logits"], batch["pseudo_beta"],
                            seq_mask)
    total = w_fape * l_fape + w_aux * l_aux + w_msa * l_msa + w_dist * l_dist
    return total, {
        "loss": total, "fape": l_fape, "aux_fape": l_aux,
        "masked_msa": l_msa, "distogram": l_dist,
    }
