#!/usr/bin/env bash
# Tier-1 CI, six legs — each leg is a named ExecutionPlan preset selected
# through the single REPRO_PLAN entry point (resolved by the one env-compat
# module, src/repro/exec/envcompat.py -> repro.exec.plan.PRESETS):
#   1. default          — KernelPolicy(enabled=True): Pallas kernels on TPU;
#                         on CPU each op runs its XLA-native leg (fused
#                         attention = online-softmax scan, fused
#                         triangle/OPM = j-block scans).
#   2. oracle           — KernelPolicy(enabled=False): pure-jnp oracles, the
#                         scores-materialized attention, and the
#                         materialized pair-stack paths (A/B legs).
#   3. interpret        — KernelPolicy(interpret=True): the Pallas kernels
#                         (fwd + the fused attention backward + the fused
#                         triangle/OPM forwards) execute in interpret mode
#                         on the kernel test modules.
#   4. triangle-oracle  — KernelPolicy(triangle='oracle', opm='oracle'):
#                         tier-1 with ONLY the pair-stack kernels pinned to
#                         their jnp oracles (the rest of the kernel set
#                         stays on its default legs) — isolates regressions
#                         to the triangle/OPM fusion itself.
#   5. multi-device     — 8 host devices: distributed DAP/GSPMD parity, the
#                         shard-mapped fused attention + triangle/OPM, and
#                         the fused attention suite, on both kernel legs.
#   6. resilience       — the fault-injection/chaos suite + the serving
#                         suite on BOTH kernel legs, with the process-wide
#                         fault schedule pinned via REPRO_FAULT_SEED
#                         (resolved by envcompat.fault_seed) so the
#                         randomized sweeps are reproducible in CI.
# Any divergence between a kernel and its oracle fails fast in legs 1/3;
# legs 2/4 prove the fallback paths stay healthy on their own.
# Final grep gates assert (a) os.environ access stays confined to the
# compat module (tests/test_exec_plan.py enforces the same in-suite), and
# (b) no bare "except Exception:" outside src/repro/resilience/ — failure
# handling must dispatch on the typed fault hierarchy, not swallow.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 leg 1/6: plan preset 'default' (XLA-native legs off-TPU) ==="
python -m pytest -x -q "$@"

echo "=== tier-1 leg 2/6: plan preset 'oracle' (REPRO_PLAN=oracle, jnp paths) ==="
REPRO_PLAN=oracle python -m pytest -x -q "$@"

if [ "$#" -gt 0 ]; then
    # Scoped developer run: legs 3-6 run fixed module lists that would ignore
    # the selection — stop here rather than silently dropping the arguments.
    echo "ci.sh: args given — scoped run, legs 1-2 only"
    exit 0
fi

echo "=== tier-1 leg 3/6: plan preset 'interpret' (Pallas interpret validation) ==="
REPRO_PLAN=interpret python -m pytest -x -q \
    tests/test_kernels.py tests/test_fused_attention.py tests/test_triangle.py

echo "=== tier-1 leg 4/6: plan preset 'triangle-oracle' (pair-stack kernels -> oracles) ==="
REPRO_PLAN=triangle-oracle python -m pytest -x -q \
    tests/test_triangle.py tests/test_evoformer.py tests/test_fused_attention.py \
    tests/test_autochunk.py tests/test_alphafold.py

echo "=== tier-1 leg 5/6: multi-device (8 host devices), both kernel legs ==="
XLA_FLAGS="--xla_force_host_platform_device_count=8" python -m pytest -x -q \
    tests/test_distributed.py tests/test_fused_attention.py tests/test_triangle.py
XLA_FLAGS="--xla_force_host_platform_device_count=8" REPRO_PLAN=oracle \
    python -m pytest -x -q tests/test_distributed.py

echo "=== tier-1 leg 6/6: resilience (fault injection + chaos), both kernel legs ==="
REPRO_FAULT_SEED=1234 python -m pytest -x -q \
    tests/test_resilience.py tests/test_serving.py
REPRO_FAULT_SEED=1234 REPRO_PLAN=oracle python -m pytest -x -q \
    tests/test_resilience.py tests/test_serving.py

echo "=== grep gate: os.environ confined to src/repro/exec/envcompat.py ==="
stray=$(grep -rn "os\.environ" src/repro --include="*.py" \
        | grep -v "repro/exec/envcompat.py" || true)
if [ -n "$stray" ]; then
    echo "$stray"
    echo "ci.sh: FAIL — os.environ access outside the env-compat module"
    exit 1
fi

echo "=== grep gate: no bare 'except Exception:' outside src/repro/resilience/ ==="
# "except Exception as err:" with typed re-dispatch is fine; a bare handler
# that can swallow anything is not — failures must stay typed so the
# engine's retry/degradation routing (and tests) can see them.
stray=$(grep -rnE "except Exception *:" src/repro --include="*.py" \
        | grep -v "repro/resilience/" || true)
if [ -n "$stray" ]; then
    echo "$stray"
    echo "ci.sh: FAIL — bare 'except Exception:' outside repro/resilience/"
    exit 1
fi

echo "ci.sh: all legs green"
