#!/usr/bin/env bash
# Tier-1 CI, four legs:
#   1. default          — Pallas kernels enabled; on CPU each op runs its
#                         XLA-native leg (fused attention = online-softmax
#                         scan), on TPU the Pallas kernels.
#   2. kernels disabled — REPRO_DISABLE_KERNELS=1: pure-jnp oracles and the
#                         scores-materialized attention (A/B path).
#   3. kernel validation— REPRO_PALLAS_INTERPRET=1: the Pallas kernels
#                         (fwd + the fused attention backward) execute in
#                         interpret mode on the kernel test modules.
#   4. multi-device     — 8 host devices: distributed DAP/GSPMD parity, the
#                         shard-mapped fused attention, and the fused
#                         attention suite, on both kernel legs.
# Any divergence between a kernel and its oracle fails fast in legs 1/3; leg
# 2 proves the fallback path stays healthy on its own.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 leg 1/4: kernels ENABLED (XLA-native legs off-TPU) ==="
python -m pytest -x -q "$@"

echo "=== tier-1 leg 2/4: kernels DISABLED (REPRO_DISABLE_KERNELS=1, oracle paths) ==="
REPRO_DISABLE_KERNELS=1 python -m pytest -x -q "$@"

if [ "$#" -gt 0 ]; then
    # Scoped developer run: legs 3/4 run fixed module lists that would ignore
    # the selection — stop here rather than silently dropping the arguments.
    echo "ci.sh: args given — scoped run, legs 1-2 only"
    exit 0
fi

echo "=== tier-1 leg 3/4: Pallas interpret validation (REPRO_PALLAS_INTERPRET=1) ==="
REPRO_PALLAS_INTERPRET=1 python -m pytest -x -q \
    tests/test_kernels.py tests/test_fused_attention.py

echo "=== tier-1 leg 4/4: multi-device (8 host devices), both kernel legs ==="
XLA_FLAGS="--xla_force_host_platform_device_count=8" python -m pytest -x -q \
    tests/test_distributed.py tests/test_fused_attention.py
XLA_FLAGS="--xla_force_host_platform_device_count=8" REPRO_DISABLE_KERNELS=1 \
    python -m pytest -x -q tests/test_distributed.py

echo "ci.sh: all legs green"
