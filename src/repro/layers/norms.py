"""Normalization layers backed by the fused Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.layers.params import Params


def init_layer_norm(d: int, dtype=jnp.float32) -> Params:
    return {"gamma": jnp.ones((d,), dtype), "beta": jnp.zeros((d,), dtype)}


def layer_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    return ops.layer_norm(x, p["gamma"], p["beta"], eps)


def init_rms_norm(d: int, dtype=jnp.float32) -> Params:
    return {"gamma": jnp.ones((d,), dtype)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv * p["gamma"].astype(jnp.float32)).astype(x.dtype)
