"""Fused LayerNorm Pallas TPU kernel (paper §IV.A.3, Fig. 9).

GPU→TPU adaptation: the paper uses a warp per row with the *Welford* merge
update so partial (mean, M2) streams held by different threads can be combined
numerically stably in one pass. On TPU the whole row lives in one VMEM tile, so
no cross-thread merging exists; we keep the one-pass property by accumulating
``sum(x)`` and ``sum(x^2)`` in fp32 inside the tile. At the row lengths in this
framework (<= ~27k, bf16 inputs) fp32 E[x^2]-E[x]^2 matches the two-pass oracle
to within bf16 resolution — asserted by the kernel test sweep.

Fusion (the actual win, as in the paper): load x once from HBM, write y once,
with statistics + affine applied in-register.

Rank-polymorphic: 2D–4D inputs run under a grid over the leading dims — the
kernel never row-flattens its input, so mesh-sharded (B, G, ...) leading dims
stay unmerged under GSPMD (a reshape merging two sharded dims would force an
all-gather of the whole representation; same contract as the shard-mapped
fused attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8
LANE = 128


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def row_grid_specs(shape, row_tile: int, c_pad: int):
    """Grid + x-block spec for an (..., R, C) tensor WITHOUT flattening the
    leading dims: one grid axis per leading dim, blocks of (1, ..., row_tile,
    c_pad). Returns (grid, block_shape, index_map)."""
    lead = tuple(shape[:-2])
    nl = len(lead)
    grid = lead + (pl.cdiv(shape[-2], row_tile),)
    block = (1,) * nl + (row_tile, c_pad)

    def ix(*g):
        return g[:nl] + (g[nl], 0)

    return grid, block, ix


def _layer_norm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float, c_actual: int):
    x = x_ref[...].astype(jnp.float32)
    x = x.reshape(x.shape[-2:])         # drop leading (1,)*nl block dims
    if c_actual != x.shape[-1]:
        lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
        valid = lane < c_actual
        x = jnp.where(valid, x, 0.0)
    count = jnp.float32(c_actual)
    s1 = jnp.sum(x, axis=-1, keepdims=True)
    s2 = jnp.sum(x * x, axis=-1, keepdims=True)
    mean = s1 / count
    var = jnp.maximum(s2 / count - mean * mean, 0.0)
    inv = jax.lax.rsqrt(var + eps)
    y = (x - mean) * inv
    y = y * g_ref[...].astype(jnp.float32)[0] + b_ref[...].astype(jnp.float32)[0]
    o_ref[...] = y.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def layer_norm_pallas(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    *,
    eps: float = 1e-5,
    interpret: bool = False,
) -> jax.Array:
    """x: (..., R, C) (2D–4D) normalized over C; gamma/beta: (C,)."""
    r, c = x.shape[-2], x.shape[-1]
    c_pad = _pad_to(c, LANE)
    row_tile = ROW_TILE if r >= ROW_TILE else r
    grid, block, ix = row_grid_specs(x.shape, row_tile, c_pad)
    kernel = functools.partial(_layer_norm_kernel, eps=eps, c_actual=c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(block, ix),
            pl.BlockSpec((1, c_pad), lambda *g: (0, 0)),
            pl.BlockSpec((1, c_pad), lambda *g: (0, 0)),
        ],
        out_specs=pl.BlockSpec(block, ix),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, gamma.reshape(1, c), beta.reshape(1, c))
