"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

  PYTHONPATH=src python -m benchmarks.roofline_report \
      [--single dryrun_single_pod.json] [--multi dryrun_multi_pod.json]
"""
import argparse
import json
import os

from repro.configs import INPUT_SHAPES, get_config
from repro.layers.params import count_params

# active params (for MODEL_FLOPS = 6*N_active*D); computed analytically from
# the configs to avoid materializing 236B params.


def n_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts, analytic."""
    d, v = cfg.d_model, cfg.vocab
    total = v * d * (1 if cfg.tie_embeddings else 2)
    active = total
    for kind, count in cfg.resolved_stages:
        mixer, _, ffn = kind.partition("+")
        if not ffn:
            ffn = "dense" if cfg.d_ff > 0 else "none"
        hd = cfg.resolved_head_dim
        if mixer in ("attn", "swa", "hymba", "hymba_full"):
            attn = d * (cfg.n_heads + 2 * cfg.n_kv) * hd + cfg.n_heads * hd * d
        elif mixer == "mla":
            m = cfg.mla
            attn = (d * m.q_lora + m.q_lora * cfg.n_heads * (m.nope_dim + m.rope_dim)
                    + d * (m.kv_lora + m.rope_dim)
                    + m.kv_lora * cfg.n_heads * (m.nope_dim + m.v_dim)
                    + cfg.n_heads * m.v_dim * d)
        elif mixer == "mlstm":
            di = 2 * d
            attn = d * 2 * di + di * 3 * di + di * d
        elif mixer == "slstm":
            attn = d * 4 * d + d * 4 * d + d * d
        else:
            attn = 0
        if mixer in ("hymba", "hymba_full"):
            di = cfg.ssm.expand * d
            attn += d * 2 * di + di * d + di * (2 * cfg.ssm.state_dim + d // 16)
        if ffn == "dense":
            dff = (cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense)
                   else cfg.d_ff)
            f_total = f_active = 3 * d * dff if cfg.act == "swiglu" \
                else 2 * d * dff
        elif ffn == "moe":
            e = cfg.moe
            per = 3 * d * e.d_ff_expert
            f_total = e.n_experts * per + e.n_shared * per
            f_active = e.top_k * per + e.n_shared * per
        else:
            f_total = f_active = 0
        total += count * (attn + f_total)
        active += count * (attn + f_active)
    return total, active


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def render(path: str, title: str):
    with open(path) as f:
        recs = json.load(f)
    print(f"\n### {title}\n")
    print("| arch | shape | status | bottleneck | t_compute (s) | t_memory (s) "
          "| t_collective (s) | HLO FLOPs/chip | model/HLO flops | mem/chip GB "
          "| fits 16GB | collectives |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        arch, shape = r["arch"], r["shape"]
        if r["status"] != "ok":
            note = r.get("reason", r.get("error", ""))[:60]
            print(f"| {arch} | {shape} | {r['status']} | {note} | | | | | | | | |")
            continue
        rf, m = r["roofline"], r["memory"]
        if arch.startswith("alphafold"):
            ratio = ""
        else:
            cfg = get_config(arch)
            sh = INPUT_SHAPES[shape]
            _, act = n_params(cfg)
            toks = sh.global_batch * (sh.seq_len if sh.kind != "decode" else 1)
            mult = 6.0 if sh.kind == "train" else 2.0
            model_f = mult * act * toks / r["chips"]  # per chip
            ratio = f"{model_f / max(rf['flops'], 1):.2f}"
        colls = ";".join(f"{k}:{v}" for k, v in
                         r["collectives"]["counts"].items())
        print(f"| {arch} | {shape} | ok | {rf['bottleneck']} "
              f"| {rf['t_compute_s']:.3g} | {rf['t_memory_s']:.3g} "
              f"| {rf['t_collective_s']:.3g} | {rf['flops']:.3g} | {ratio} "
              f"| {fmt_bytes(m['per_device_bytes'])} | {m['fits_16GB']} "
              f"| {colls} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", default="dryrun_single_pod.json")
    ap.add_argument("--multi", default="dryrun_multi_pod.json")
    args = ap.parse_args()
    if os.path.exists(args.single):
        render(args.single, "Single-pod mesh 16x16 (256 chips)")
    if os.path.exists(args.multi):
        render(args.multi, "Multi-pod mesh 2x16x16 (512 chips)")


if __name__ == "__main__":
    main()
