"""Selective state-space (Mamba-style) block, used by the Hymba hybrid arch.

Training/prefill run the recurrence as a jax.lax.associative_scan over time
(the TPU-native adaptation of Mamba's CUDA selective-scan kernel: the
recurrence h_t = a_t * h_{t-1} + b_t is a first-order linear scan, which the
associative combinator parallelizes in O(log S) depth — this is also the DAP
story for recurrent archs: chunked sequence shards hand the carry across
devices). Decode is the O(1)-state recurrent step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.layers.params import Params, init_dense, dense, trunc_normal


def init_mamba(key, d_model: int, ssm: SSMConfig, d_inner: int | None = None) -> Params:
    d_inner = d_inner or ssm.expand * d_model
    dt_rank = ssm.dt_rank or max(1, d_model // 16)
    ks = iter(jax.random.split(key, 8))
    return {
        "in_proj": init_dense(next(ks), d_model, 2 * d_inner, bias=False),
        "conv_w": trunc_normal(next(ks), (ssm.conv_width, d_inner), 1.0),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "x_proj": init_dense(next(ks), d_inner, dt_rank + 2 * ssm.state_dim,
                             bias=False),
        "dt_proj": init_dense(next(ks), dt_rank, d_inner, bias=True),
        # A initialized to -[1..state] (S4D-real), stored as log.
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ssm.state_dim + 1, dtype=jnp.float32),
            (d_inner, ssm.state_dim))),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": init_dense(next(ks), d_inner, d_model, bias=False,
                               zero_init=True),
    }


def _ssm_params(p, x_in, ssm: SSMConfig):
    """x_in: (B, S, d_inner) post-conv. Returns discretized a, bx, C, D."""
    dt_rank = p["dt_proj"]["w"].shape[0]
    proj = dense(p["x_proj"], x_in)
    dt, B, C = jnp.split(proj, [dt_rank, dt_rank + ssm.state_dim], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt).astype(jnp.float32))  # (B,S,di)
    A = -jnp.exp(p["A_log"])                                  # (di, n)
    a = jnp.exp(dt[..., None] * A)                            # (B,S,di,n)
    bx = (dt * x_in.astype(jnp.float32))[..., None] * B[:, :, None, :].astype(jnp.float32)
    return a, bx, C.astype(jnp.float32), p["D"]


def _conv1d(p, x, ssm: SSMConfig, conv_state=None):
    """Causal depthwise conv; x (B, S, di). Returns (y, new_conv_state)."""
    w = p["conv_w"].astype(x.dtype)                           # (W, di)
    kw = ssm.conv_width
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], kw - 1, x.shape[-1]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                    # (B, S+W-1, di)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(kw))
    y = y + p["conv_b"].astype(x.dtype)
    new_state = xp[:, -(kw - 1):]
    return y, new_state


def mamba_forward(p: Params, x: jax.Array, ssm: SSMConfig):
    """Full-sequence forward (train/prefill). x: (B, S, d). Returns
    (y (B, S, d), state) where state = {"h": (B, di, n), "conv": ...}."""
    xz = dense(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, conv_state = _conv1d(p, x_in, ssm)
    x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)
    a, bx, C, D = _ssm_params(p, x_c, ssm)

    # associative first-order scan over time: h_t = a_t h_{t-1} + bx_t
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_s, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, C)                      # (B, S, di)
    y = y + D * x_c.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = dense(p["out_proj"], y.astype(x.dtype))
    state = {"h": h[:, -1], "conv": conv_state}
    return out, state


def mamba_decode(p: Params, x: jax.Array, state, ssm: SSMConfig):
    """Single-step decode. x: (B, 1, d); state h (B, di, n)."""
    xz = dense(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, conv_state = _conv1d(p, x_in, ssm, conv_state=state["conv"])
    x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)
    a, bx, C, D = _ssm_params(p, x_c, ssm)
    h = a[:, 0] * state["h"] + bx[:, 0]                        # (B, di, n)
    y = jnp.einsum("bdn,bn->bd", h, C[:, 0])[:, None]
    y = y + D * x_c.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = dense(p["out_proj"], y.astype(x.dtype))
    return out, {"h": h, "conv": conv_state}


def init_mamba_state(batch: int, d_inner: int, ssm: SSMConfig):
    return {
        "h": jnp.zeros((batch, d_inner, ssm.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, ssm.conv_width - 1, d_inner), jnp.float32),
    }
