"""Serving launcher CLI (reduced configs on CPU; production mesh on TPU).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b --reduced \
      --requests 6 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.exec.plan import PRESETS, preset
from repro.models.decoder import init_model
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan", default="default", choices=sorted(PRESETS),
                    help="ExecutionPlan preset the engine binds")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced_variant=args.reduced)
    params = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(params, cfg, n_slots=args.slots,
                           max_seq=args.max_seq, plan=preset(args.plan))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        engine.submit(rng.integers(0, cfg.vocab, size=(8,)),
                      max_new_tokens=args.max_new,
                      temperature=args.temperature)
    finished = engine.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in finished)
    print(f"{args.arch}: {len(finished)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
