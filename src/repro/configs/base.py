"""Config dataclasses + the assigned input shapes.

Every assigned architecture gets one file in this package defining
``CONFIG = ModelConfig(...)`` (exact assigned numbers, source cited) and
``REDUCED = reduced(CONFIG)`` — a same-family shrink (<=2 layers, d_model<=512,
<=4 experts) used by the CPU smoke tests. Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0
    first_dense: int = 0          # leading dense layers (DeepSeek: 1)
    capacity_factor: float = 1.25
    aux_weight: float = 0.001
    d_ff_dense: int = 0           # d_ff of the leading dense layers
    # dispatch groups: capacity selection is done per token-group so routing
    # metadata never crosses shards (set to the DAP degree by the sharding
    # plan; 1 = single global group).
    n_groups: int = 1


@dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    expand: int = 2
    conv_width: int = 4
    dt_rank: int = 0              # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class ModalityConfig:
    kind: str                     # "vision" | "audio"
    n_prefix_tokens: int          # patch/frame embeddings prepended to text
    embed_dim: int                # dim of the (stub) frontend output


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    source: str                   # citation from the assignment
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "swiglu"           # swiglu | gelu
    tie_embeddings: bool = False
    sliding_window: int = 0       # 0 -> full attention
    # layer pattern: tuple of (kind, count); kinds: attn, swa, mlstm, slstm,
    # hymba, hymba_full. Empty -> ("attn", n_layers).
    stages: tuple = ()
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    modality: Optional[ModalityConfig] = None
    # True when the arch supports the long_500k shape (sub-quadratic path).
    subquadratic: bool = False
    # --- attention execution policy (perf levers; see EXPERIMENTS.md §Perf).
    # attn_q_block=0 -> single full-length q block (no q scan: under DAP the
    # q axis is already sharded, so q-blocking only causes GSPMD resharding).
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    # gather KV once per layer (replicated over 'model') before the blockwise
    # scan, instead of letting GSPMD re-gather inside every scan step.
    gather_kv: bool = False
    # store decode KV caches as int8 with per-(layer,head) scales (beyond-
    # paper: halves cache bytes; needed for qwen1.5-32b decode_32k to fit).
    kv_cache_int8: bool = False
    # bf16 AdamW moments (beyond-paper: 12 -> 8 bytes/param of sharded state;
    # needed for deepseek-v2-236b train_4k to fit the 256-chip mesh).
    opt_state_bf16: bool = False
    # serve-time: replicate (bf16) params across the mesh instead of ZeRO
    # sharding — kills the per-layer weight all-gathers that dominate the
    # decode collective term for small models (paper-faithful DAP semantics:
    # full params per device).
    serve_replicate_params: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_stages(self) -> tuple:
        return self.stages or (("attn", self.n_layers),)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Same-family smoke-test shrink: <=2 layers, d_model<=512, <=4 experts."""
    changes: dict = dict(
        n_layers=2,
        d_model=min(cfg.d_model, 128),
        n_heads=min(cfg.n_heads, 4),
        n_kv=min(cfg.n_kv, 2),
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 512),
        head_dim=32 if cfg.head_dim else 0,
    )
    if cfg.n_kv == cfg.n_heads:  # keep MHA archs MHA
        changes["n_kv"] = changes["n_heads"]
    if cfg.moe:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, n_shared=min(cfg.moe.n_shared, 1),
            d_ff_expert=64, first_dense=min(cfg.moe.first_dense, 1),
            d_ff_dense=128 if cfg.moe.d_ff_dense else 0,
        )
    if cfg.mla:
        changes["mla"] = MLAConfig(q_lora=64, kv_lora=32, rope_dim=16,
                                   nope_dim=32, v_dim=32)
    if cfg.ssm:
        changes["ssm"] = dataclasses.replace(cfg.ssm, state_dim=8)
    if cfg.modality:
        changes["modality"] = dataclasses.replace(
            cfg.modality, n_prefix_tokens=8, embed_dim=changes["d_model"])
    if cfg.sliding_window:
        changes["sliding_window"] = 16
    if cfg.stages:
        # shrink the pattern to 2 layers keeping kind diversity
        kinds = []
        for kind, cnt in cfg.stages:
            if kind not in kinds:
                kinds.append(kind)
        kinds = kinds[:2] or ["attn"]
        if len(kinds) == 1:
            kinds = kinds * 2
        changes["stages"] = tuple((k, 1) for k in kinds)
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
