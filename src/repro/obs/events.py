"""The stable event schema of the obs JSONL sink, plus its validator.

One JSON object per line. Every event carries the common fields

    seq     int   emit-order sequence number (the deterministic ordering
                  key — strictly increasing within a stream)
    t_ns    int   monotonic ns since tracer start (never wall clock)
    kind    str   one of KINDS below
    name    str   kind-specific name (span name, counter name, request
                  phase, interned-def label, jit site)

and the kind-specific fields listed in KINDS. ``attrs`` is always a JSON
object of free-form, kind-documented attributes — adding an attr is a
backward-compatible schema change; adding/removing a required field or a
kind bumps SCHEMA_VERSION.

Kinds:

    meta        run metadata (model/engine facts the report needs:
                param_count, param_bytes, cache_row_bytes, n_slots, ...).
    def         an interned value definition: ``name`` is the label
                (e.g. "plan:0"), ``value`` the full payload (e.g. the
                serialized ExecutionPlan). Later events reference the
                label — the full plan appears exactly once per stream.
    span        a closed span: span_id/parent_id give the nesting tree,
                t_start_ns/dur_ns the interval, status "ok"|"error".
                jax-timed leaf spans carry attrs.dispatch_ns/block_ns
                (host dispatch incl. compile on a cold cache / device
                execute).
    counter     monotonic counter increment: delta and the cumulative
                value.
    gauge       point-in-time measurement (queue_depth, occupancy, ...).
    request     serving-engine lifecycle event: ``name`` is the phase
                (REQUEST_PHASES), ``uid`` the request id (null for
                rejected-at-submit, which never got one).
    train_step  one train-loop step: step index, host dispatch dur_ns
                (no sync), optional tokens-per-step for throughput, and
                metrics {loss, grad_norm, nonfinite_skips} resolved at
                serialization time.
    jit_entry   one call through a plan-keyed jit site: key (the interned
                plan label), cache "miss"|"hit".

Request lifecycle (the typed per-request stream):

    queued -> admitted -> (prefill span) -> per-plan-group decode spans
           -> done | failed
    with retried / degraded / quarantined / rejected interleaved as the
    failure machinery routes the request. Exactly one terminal phase
    (done|failed) per queued uid — ``repro.obs.report.reconcile`` checks
    this, and the chaos-reconciliation test proves it under injected
    faults.

This module is pure Python (no jax): CI's schema-validation leg and the
tests feed it raw dicts/files.
"""
from __future__ import annotations

import json

SCHEMA_VERSION = 1

_NUM = (int, float)

#: kind -> {field: allowed types} beyond the common fields. A ``None`` in
#: the tuple marks the field as nullable.
KINDS: dict[str, dict[str, tuple]] = {
    "meta": {"attrs": (dict,)},
    "def": {"value": (dict, str, list)},
    "span": {"span_id": (int,), "parent_id": (int, None),
             "t_start_ns": _NUM, "dur_ns": _NUM, "status": (str,),
             "attrs": (dict,)},
    "counter": {"delta": _NUM, "value": _NUM, "attrs": (dict,)},
    "gauge": {"value": _NUM, "attrs": (dict,)},
    "request": {"uid": (int, None), "attrs": (dict,)},
    "train_step": {"step": (int,), "dur_ns": _NUM, "metrics": (dict,),
                   "tokens": (int, float, None)},
    "jit_entry": {"key": (str,), "cache": (str,)},
}

REQUEST_PHASES = ("queued", "rejected", "admitted", "prefill", "done",
                  "failed", "retried", "degraded", "quarantined")
TERMINAL_PHASES = ("done", "failed")
SPAN_STATUSES = ("ok", "error")
JIT_CACHE = ("miss", "hit")


def _typecheck(value, types) -> bool:
    for t in types:
        if t is None:
            if value is None:
                return True
        elif isinstance(value, t) and not (t in (int, float)
                                           and isinstance(value, bool)):
            return True
    return False


def validate_event(ev) -> list[str]:
    """Schema problems of one event dict (empty = valid)."""
    problems: list[str] = []
    if not isinstance(ev, dict):
        return [f"event is not an object: {ev!r}"]
    where = f"event seq={ev.get('seq')!r}"
    for field, types in (("seq", (int,)), ("t_ns", _NUM), ("kind", (str,)),
                         ("name", (str,))):
        if field not in ev:
            problems.append(f"{where}: missing common field {field!r}")
        elif not _typecheck(ev[field], types):
            problems.append(f"{where}: {field}={ev[field]!r} has wrong type")
    kind = ev.get("kind")
    if kind not in KINDS:
        problems.append(f"{where}: unknown kind {kind!r}")
        return problems
    for field, types in KINDS[kind].items():
        if field not in ev:
            problems.append(f"{where} ({kind}): missing field {field!r}")
        elif not _typecheck(ev[field], types):
            problems.append(
                f"{where} ({kind}): {field}={ev[field]!r} has wrong type")
    extra = set(ev) - {"seq", "t_ns", "kind", "name"} - set(KINDS[kind])
    if extra:
        problems.append(f"{where} ({kind}): undeclared fields {sorted(extra)}"
                        " — extend the schema, don't freelance")
    if kind == "request" and ev.get("name") not in REQUEST_PHASES:
        problems.append(f"{where}: unknown request phase {ev.get('name')!r}")
    if kind == "span" and ev.get("status") not in SPAN_STATUSES:
        problems.append(f"{where}: unknown span status {ev.get('status')!r}")
    if kind == "jit_entry" and ev.get("cache") not in JIT_CACHE:
        problems.append(f"{where}: jit_entry cache={ev.get('cache')!r}")
    return problems


def validate_events(events) -> list[str]:
    """Schema problems of a whole stream, including seq monotonicity."""
    problems: list[str] = []
    last_seq = -1
    for ev in events:
        problems.extend(validate_event(ev))
        seq = ev.get("seq") if isinstance(ev, dict) else None
        if isinstance(seq, int):
            if seq <= last_seq:
                problems.append(
                    f"event seq={seq}: not strictly increasing "
                    f"(previous {last_seq})")
            last_seq = seq
    return problems


def read_jsonl(path) -> list[dict]:
    """Load an event stream written by ``Tracer.dump_jsonl``."""
    events: list[dict] = []
    with open(path, encoding="utf-8") as fh:
        for i, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as err:
                raise ValueError(f"{path}:{i}: not JSON: {err}") from err
    return events
