"""Hymba-1.5B [arXiv:2411.13676]: hybrid-head blocks — attention and Mamba
heads in parallel within each layer; full attention at 3 layers (first,
middle, last), sliding-window elsewhere; ssm_state=16."""
from repro.configs.base import ModelConfig, SSMConfig, reduced

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", source="arXiv:2411.13676",
    n_layers=32, d_model=1600, n_heads=25, n_kv=5, d_ff=5504, vocab=32001,
    sliding_window=1024, subquadratic=True,
    stages=(("hymba_full", 1), ("hymba", 14), ("hymba_full", 1),
            ("hymba", 15), ("hymba_full", 1)),
    ssm=SSMConfig(state_dim=16, expand=2, conv_width=4),
)
REDUCED = reduced(CONFIG, stages=(("hymba_full", 1), ("hymba", 1)))
