"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = wire_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). wire_bytes are
parsed from the optimized HLO text: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute payload, scaled by the
ring-algorithm wire factor for its replica-group size.

Ops inside while-loops (lax.scan layer stacks, flash-attention KV loops)
appear once in the HLO but execute trip-count times; we reconstruct per-
computation execution multipliers from the `known_trip_count` annotations
(products across nested loops) and scale both the collective payloads and
the cost_analysis numbers accordingly.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-to-all", "all-gather", "all-reduce", "reduce-scatter",
                "collective-permute")

# wire factor per participant for ring algorithms (group size n):
_WIRE_FACTOR = {
    "all-gather": lambda n: (n - 1) / n,       # payload = full output
    "reduce-scatter": lambda n: (n - 1) / n,   # payload = full input
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def _shape_bytes(type_str: str) -> int:
    """'bf16[2,512,64]' or '(bf16[...], f32[...])' -> total bytes."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """computation name -> its body lines."""
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$",
                     line)
        if m:
            current = m.group(1)
            comps[current] = []
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
                continue
            comps[current].append(line)
    return comps


def _execution_scales(comps: dict[str, list[str]]) -> dict[str, float]:
    """Per-computation execution multiplier from nested while trip counts."""
    # edges: parent comp -> (child comp, multiplier)
    edges: dict[str, list[tuple[str, float]]] = {name: [] for name in comps}
    for name, lines in comps.items():
        for ln in lines:
            mult = 1.0
            mt = re.search(r'known_trip_count[^\d]*(\d+)', ln)
            if " while(" in ln and mt:
                mult = float(mt.group(1))
            for attr in ("body", "condition", "to_apply", "calls",
                         "branch_computations"):
                for m in re.finditer(attr + r"=\{?%?([\w.\-]+)", ln):
                    child = m.group(1)
                    if child in comps:
                        edges[name].append((child, mult))

    # propagate from entry (computations not referenced by others)
    referenced = {c for lst in edges.values() for c, _ in lst}
    scales = {name: (1.0 if name not in referenced else 0.0)
              for name in comps}
    # relax: a few passes suffice (call graphs are shallow)
    for _ in range(12):
        changed = False
        for parent, lst in edges.items():
            for child, mult in lst:
                cand = scales[parent] * mult
                if cand > scales.get(child, 0.0):
                    scales[child] = cand
                    changed = True
        if not changed:
            break
    return scales


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    payload_bytes: dict = field(default_factory=dict)
    wire_bytes: float = 0.0


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    """Sum collective payloads from optimized HLO, scaled by loop trips."""
    comps = _split_computations(hlo_text)
    scales = _execution_scales(comps)
    stats = CollectiveStats()
    for name, lines in comps.items():
        scale = max(scales.get(name, 1.0), 1.0)
        for line in lines:
            for op in _COLLECTIVES:
                m = re.search(
                    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s+"
                    + op + r"(-start)?\(", line)
                if m:
                    payload = _shape_bytes(m.group(1))
                    n = _group_size(line, default_group)
                    factor = _WIRE_FACTOR[op](max(n, 2))
                    stats.counts[op] = stats.counts.get(op, 0) + 1
                    stats.payload_bytes[op] = (
                        stats.payload_bytes.get(op, 0.0) + payload * scale)
                    stats.wire_bytes += payload * factor * scale
                    break
    return stats


def count_collective_ops(hlo_text: str) -> dict[str, int]:
    """Static per-op collective counts: each `= ... <op>(` definition counted
    once, NO trip scaling (contrast parse_collectives, which models executed
    volume). This is what the CollectiveBudget contract wants: the scan body
    is traced once, so the static count is the per-block count. Async pairs
    count once (the `-start`; `-done` only re-states the operand)."""
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            if re.search(r"=\s*(?:\([^)]*\)|\w+\[[\d,]*\]\S*)\s+"
                         + op + r"(-start)?\(", line):
                counts[op] = counts.get(op, 0) + 1
                break
    return counts


def cost_scale_factor(hlo_text: str) -> float:
    """cost_analysis() counts while bodies once; the dominant layer-stack loop
    multiplies real cost. We use the max product of nested trip counts as the
    whole-program scale (exact for cost dominated by the layer scan)."""
    comps = _split_computations(hlo_text)
    scales = _execution_scales(comps)
    return max(list(scales.values()) + [1.0])


_SKIP_BYTE_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
                  "bitcast(", "after-all(", "iota(", "partition-id(",
                  "replica-id(")


def _symbols(lines: list[str]) -> dict[str, tuple[list[int], int]]:
    """name -> (result dims, result bytes) for ops defined in a computation."""
    table: dict[str, tuple[list[int], int]] = {}
    for ln in lines:
        m = re.match(r"\s*%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]))",
                     ln)
        if m:
            md = re.match(r"\w+\[([\d,]*)\]", m.group(2))
            dims = ([int(d) for d in md.group(1).split(",") if d]
                    if md else [])
            table[m.group(1)] = (dims, _shape_bytes(m.group(2)))
    return table


def _dot_flops(line: str, symtab: dict[str, list[int]]) -> float:
    """FLOPs of a `dot` op: 2 * prod(result dims) * prod(contracting sizes).
    Operand shapes come from the inline operand type when the HLO text prints
    one (``dot(f32[64,32]{1,0} %arg, ...)``, newer XLA) and are otherwise
    resolved via the computation's symbol table (name-only operands)."""
    m = re.search(r"=\s*\w+\[([\d,]*)\]\S*\s+dot\(\s*"
                  r"(?:(\w+\[[\d,]*\])\S*\s+)?%?([\w.\-]+)", line)
    if not m:
        return 0.0
    res_dims = [int(d) for d in m.group(1).split(",") if d] or [1]
    if m.group(2):
        md = re.match(r"\w+\[([\d,]*)\]", m.group(2))
        lhs_dims = [int(d) for d in md.group(1).split(",") if d]
    else:
        lhs_dims = (symtab.get(m.group(3)) or ([], 0))[0]
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    if lhs_dims and mc and mc.group(1):
        for i in mc.group(1).split(","):
            ii = int(i)
            if ii < len(lhs_dims):
                contract *= lhs_dims[ii]
    out = 1
    for d in res_dims:
        out *= d
    return 2.0 * out * contract


def _line_io_bytes(line: str, symtab) -> int:
    """HBM-traffic estimate of one top-level HLO op: result bytes + operand
    bytes (fusion I/O == the fused kernel's memory traffic). Operands are
    printed by name; sizes resolved via the symbol table."""
    m = re.search(r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s+([\w\-]+)\(",
                  line)
    if not m:
        return 0
    out_b = _shape_bytes(m.group(1))
    in_b = 0
    mop = re.search(r"[\w\-]+\((.*?)\)(?:,|$)", line)
    if mop:
        for name in re.findall(r"%([\w.\-]+)", mop.group(1)):
            in_b += (symtab.get(name) or ([], 0))[1]
    return out_b + in_b


_SLICE_OPS = (" dynamic-slice(", " gather(")


def _fusion_root_out_bytes(lines: list[str]) -> float | None:
    """If a fused computation's ROOT is a dynamic-update-slice, the fusion's
    output HBM traffic is the update slice (in-place slot write), not the
    full buffer. Returns effective out bytes or None."""
    symtab = _symbols(lines)
    for ln in lines:
        if "ROOT" in ln and " dynamic-update-slice(" in ln:
            mu = re.search(r"dynamic-update-slice\(\s*%[\w.\-]+,\s*%([\w.\-]+)",
                           ln)
            if mu:
                return float((symtab.get(mu.group(1)) or ([], 0))[1])
    return None


def _fusion_param_effective(lines: list[str]) -> dict[int, float]:
    """For a fused computation: parameter index -> effective HBM bytes.

    A parameter consumed only through dynamic-slice/gather contributes the
    *slice* bytes, not its full size (the scan-body weight stack is the
    canonical case: (L, d, f) stacked weights, (1, d, f) read per step).
    A parameter updated through dynamic-update-slice contributes the update
    bytes (read+write of the touched slot)."""
    symtab = _symbols(lines)
    params: dict[str, int] = {}
    for ln in lines:
        m = re.match(r"\s*%?([\w.\-]+)\s*=\s*\S+\s+parameter\((\d+)\)", ln)
        if m:
            params[m.group(1)] = int(m.group(2))
    eff: dict[int, float] = {}
    consumers: dict[str, list[str]] = {p: [] for p in params}
    for ln in lines:
        mop = re.search(r"=\s*\S+\s+([\w\-]+)\((.*?)\)(?:,|$)", ln)
        if not mop:
            continue
        for name in re.findall(r"%([\w.\-]+)", mop.group(2)):
            if name in consumers:
                consumers[name].append(ln)
    for pname, uses in consumers.items():
        idx = params[pname]
        full = (symtab.get(pname) or ([], 0))[1]
        if uses and all(
            any(s in u for s in _SLICE_OPS) or " dynamic-update-slice(" in u
            for u in uses
        ):
            b = 0.0
            for u in uses:
                if " dynamic-update-slice(" in u:
                    mu = re.search(r"dynamic-update-slice\(\s*%[\w.\-]+,\s*"
                                   r"%([\w.\-]+)", u)
                    upd = (symtab.get(mu.group(1)) or ([], 0))[1] if mu else 0
                    b += 2.0 * upd
                else:
                    mres = re.search(r"=\s*((?:\w+\[[\d,]*\]))", u)
                    b += _shape_bytes(mres.group(1)) if mres else 0
            eff[idx] = max(b, 1.0)
        else:
            eff[idx] = float(full)
    return eff


def hlo_cost(hlo_text: str) -> tuple[float, float]:
    """(flops, hbm_bytes) of the per-device SPMD program, with while-loop
    trip scaling.

    flops: every `dot` op, in whatever computation, scaled by its execution
    multiplier (fused or not — MXU work is MXU work).
    bytes: I/O of top-level ops in non-fusion computations (a fusion's HBM
    traffic is its operands + result, with dynamic-slice-consumed operands
    counted at slice size), scaled.
    """
    comps = _split_computations(hlo_text)
    scales = _execution_scales(comps)
    fused = set()
    for lines in comps.values():
        for ln in lines:
            if " fusion(" in ln:
                for m in re.finditer(r"calls=%?([\w.\-]+)", ln):
                    fused.add(m.group(1))
    fusion_eff = {name: _fusion_param_effective(comps[name])
                  for name in fused if name in comps}
    fusion_out = {name: _fusion_root_out_bytes(comps[name])
                  for name in fused if name in comps}

    flops = 0.0
    bytes_ = 0.0
    for name, lines in comps.items():
        scale = max(scales.get(name, 1.0), 1.0)
        body_is_fused = name in fused or name.startswith("fused")
        symtab = _symbols(lines)
        for ln in lines:
            if " dot(" in ln:
                flops += _dot_flops(ln, symtab) * scale
            if body_is_fused:
                continue
            if any(op in ln for op in _SKIP_BYTE_OPS):
                continue
            if "=" not in ln:
                continue
            bytes_ += _op_bytes(ln, symtab, fusion_eff, fusion_out) * scale
    return flops, bytes_


def _op_bytes(line: str, symtab, fusion_eff, fusion_out) -> float:
    """HBM bytes of one top-level op with slice-aware special cases."""
    mres = re.search(r"=\s*((?:\([^=]*?\))|(?:\w+\[[\d,]*\]\S*))\s+([\w\-]+)\(",
                     line)
    if not mres:
        return 0.0
    out_b = _shape_bytes(mres.group(1))
    op = mres.group(2)
    mop = re.search(r"[\w\-]+\((.*?)\)(?:,|$)", line)
    operands = re.findall(r"%([\w.\-]+)", mop.group(1)) if mop else []
    if op in ("dynamic-slice", "gather"):
        return 2.0 * out_b
    if op == "dynamic-update-slice":
        upd = (symtab.get(operands[1]) or ([], 0))[1] if len(operands) > 1 else 0
        return 2.0 * upd
    if op == "scatter":
        upd = (symtab.get(operands[-1]) or ([], 0))[1] if operands else 0
        return 2.0 * upd
    if op == "fusion":
        mcalls = re.search(r"calls=%?([\w.\-]+)", line)
        cname = mcalls.group(1) if mcalls else None
        eff = fusion_eff.get(cname, {})
        root_out = fusion_out.get(cname)
        if root_out is not None:
            out_b = 2.0 * root_out
        in_b = 0.0
        for i, name in enumerate(operands):
            full = (symtab.get(name) or ([], 0))[1]
            in_b += eff.get(i, float(full))
        return out_b + in_b
    in_b = sum((symtab.get(n) or ([], 0))[1] for n in operands)
    return out_b + in_b


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    chips: int
    peak_flops: float
    hbm_bw: float
    ici_bw: float

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * self.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / (self.chips * self.ici_bw)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def model_flops(shape, n_params_active: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference forward)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_params_active * tokens
