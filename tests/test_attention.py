"""Attention strategy tests: every execution strategy vs a naive reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.layers.attention import (
    AttnDims,
    _expand_kv,
    blockwise_attention,
    decode_attention,
    evoformer_attention,
    init_attention,
    project_qkv,
    output_proj,
    sliding_window_attention,
)

HD = 16


def ref_attn(q, k, v, causal=True, window=None, q_offset=0, bias=None):
    kk = _expand_kv(k, q.shape[2])
    vv = _expand_kv(v, q.shape[2])
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(q.shape[-1])
    if bias is not None:
        s = s + bias
    if causal:
        qpos = q_offset + jnp.arange(q.shape[1])
        kpos = jnp.arange(kk.shape[1])
        m = qpos[:, None] >= kpos[None, :]
        if window is not None:
            m &= kpos[None, :] > qpos[:, None] - window - 1
        s = jnp.where(m, s, -1e9)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.fixture
def qkv():
    B, S, H, KV = 2, 64, 4, 2
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, HD))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, HD))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, HD))
    return q, k, v


@pytest.mark.parametrize("q_block,kv_block", [(16, 16), (64, 32), (8, 64)])
def test_blockwise_matches_reference(qkv, q_block, kv_block):
    q, k, v = qkv
    got = blockwise_attention(q, k, v, causal=True, q_block=q_block,
                              kv_block=kv_block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref_attn(q, k, v)),
                               atol=2e-5)


def test_blockwise_offset_shard_semantics(qkv):
    q, k, v = qkv
    S2 = 32
    got = blockwise_attention(q[:, S2:], k, v, causal=True, q_offset=S2,
                              q_block=16, kv_block=16)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref_attn(q, k, v))[:, S2:],
                               atol=2e-5)


@pytest.mark.parametrize("window", [8, 24, 64])
def test_sliding_window_matches_reference(qkv, window):
    q, k, v = qkv
    got = sliding_window_attention(q, k, v, window=window, q_block=16)
    want = ref_attn(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_matches_reference(qkv):
    q, k, v = qkv
    t = 42
    got = decode_attention(q[:, t:t + 1], k, v,
                           jnp.array([t + 1, t + 1]))
    np.testing.assert_allclose(np.asarray(got)[:, 0],
                               np.asarray(ref_attn(q, k, v))[:, t],
                               atol=2e-5)


def test_decode_respects_lengths(qkv):
    """Entries beyond cache_len must not affect the result."""
    q, k, v = qkv
    t = 20
    got1 = decode_attention(q[:, t:t + 1], k, v, jnp.array([t + 1, t + 1]))
    k2 = k.at[:, t + 1:].set(999.0)
    v2 = v.at[:, t + 1:].set(-999.0)
    got2 = decode_attention(q[:, t:t + 1], k2, v2, jnp.array([t + 1, t + 1]))
    np.testing.assert_allclose(np.asarray(got1), np.asarray(got2), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([16, 32, 48]), h=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 1000))
def test_causality_property(s, h, seed):
    """Perturbing future tokens never changes past outputs."""
    B = 1
    q = jax.random.normal(jax.random.PRNGKey(seed), (B, s, h, HD))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, s, h, HD))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, s, h, HD))
    cut = s // 2
    out1 = blockwise_attention(q, k, v, causal=True, q_block=8, kv_block=8)
    k2 = k.at[:, cut:].add(5.0)
    v2 = v.at[:, cut:].add(-3.0)
    out2 = blockwise_attention(q, k2, v2, causal=True, q_block=8, kv_block=8)
    np.testing.assert_allclose(np.asarray(out1[:, :cut]),
                               np.asarray(out2[:, :cut]), atol=1e-5)


def test_evoformer_attention_bias_mask():
    n, s, h = 3, 10, 4
    q = jax.random.normal(jax.random.PRNGKey(0), (n, s, h, HD))
    k = jax.random.normal(jax.random.PRNGKey(1), (n, s, h, HD))
    v = jax.random.normal(jax.random.PRNGKey(2), (n, s, h, HD))
    bias = jax.random.normal(jax.random.PRNGKey(3), (h, s, s))
    got = evoformer_attention(q, k, v, bias=bias)
    want = ref_attn(q, k, v, causal=False, bias=bias[None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_gqa_project_shapes_and_merged_gemm():
    d, h, kv = 32, 4, 2
    p = init_attention(jax.random.PRNGKey(0), d, h, kv, HD, qkv_bias=True,
                       gating=True)
    assert p["wqkv"]["w"].shape == (d, (h + 2 * kv) * HD)  # merged QKV
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, d))
    q, k, v = project_qkv(p, x, AttnDims(h, kv, HD), jnp.float32)
    assert q.shape == (2, 6, h, HD)
    assert k.shape == (2, 6, kv, HD)
    ctx = jnp.ones((2, 6, h, HD))
    out = output_proj(p, ctx, x_for_gate=x)
    assert out.shape == (2, 6, d)
