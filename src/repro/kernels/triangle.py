"""Fused tiled triangle-multiplication + outer-product-mean kernels.

FastFold's kernel profiling (§V) and ScaleFold's post-attention breakdown both
point at the pair stack's einsum+gate+norm chains once attention is fused:
the triangular multiplicative updates materialize a full ``(B, i, j, c)``
fp32 product of the gathered ``(B, r, k, c)`` operand before the output
LayerNorm/projection/gate consume it, and the outer-product-mean materializes
a ``(B, i, j, c, c)`` fp32 outer-product transient before the
mask-normalization and c²→d projection collapse it. Both transients dominate
pair-stack HBM traffic at long sequence length. This module fuses each chain
into one sweep so the transient never hits HBM at full size.

Three legs per op (selected by ``ops.fused_triangle_mult`` /
``ops.fused_outer_product_mean``):

* **Pallas TPU kernel** (``fused_triangle_pallas`` / ``fused_opm_pallas``) —
  the target. Triangle: grid ``(B, I/i_t, J/j_t, K/k_t)`` with the
  contraction (k) innermost; each cell loads raw ``a``/gate/mask tiles,
  applies the input gating + pair mask in VMEM (the gated left operand never
  round-trips to HBM), and accumulates the ``(C, i_t, j_t)`` fp32 product in
  scratch; the epilogue at the last k step runs the output LayerNorm (fp32,
  one-pass E[x²]−E[x]² stats, lane-masked for padded C), the c→d output
  GEMM, and the ``bias_sigmoid_mul`` output gate before the single HBM write
  of the ``(i_t, j_t, D)`` result — plus the per-tile (mean, inv) stats the
  recompute backward reuses. OPM: grid ``(B, I/i_t, J/j_t, S/s_t)`` with the
  sequence (s) innermost, accumulating the ``(i_t·C, j_t·C)`` fp32 outer
  product and the ``(i_t, j_t)`` mask-norm in scratch; the epilogue divides
  by the fp32 mask normalization and contracts c² → d in VMEM, so the
  ``(B, i, j, c, c)`` transient exists only as one tile.

* **XLA-native leg** (``fused_triangle_xla`` / ``fused_opm_xla``) — non-TPU
  backends (mirrors ``flash_attention_xla``): a ``lax.scan`` over j output
  blocks with the same epilogue math fused into each block, bounding the
  fp32 transient at ``(B, I, j_block, C)`` (triangle) /
  ``(B, I, j_block, C²)`` (OPM) instead of the full ``(B, I, J, ·)``.

* **jnp oracle** (``ref.triangle_mult_ref`` / ``ref.outer_product_mean_ref``)
  — the materialized baseline used for parity tests, for the plan's oracle
  legs (``KernelPolicy(enabled=False)`` / ``triangle='oracle'`` /
  ``opm='oracle'`` — the old env toggles, see repro/exec/envcompat.py), and
  for out-of-envelope dtypes.

Backward: a recompute ``custom_vjp`` (defined in ops.py over
``triangle_mult_bwd`` / ``opm_bwd`` below) saves only the inputs plus the
per-tile LayerNorm stats (mean, inv) — the backward rebuilds the product
tile-by-tile over j blocks in one ``lax.scan``, so the fp32 transient of the
backward matches the forward's bound instead of storing ``(B, I, J, C)`` /
``(B, I, J, C²)`` residuals.

Tiling knobs: the triangle op's ``tile`` is the k accumulation tile of the
Pallas grid and the j output block of the XLA leg + backward recompute; the
OPM op's ``tile`` is the s accumulation tile of the Pallas grid and the j
output block of the XLA leg + backward. The AutoChunk planner
(repro.memory.autochunk) picks both (``tri_k_tile`` / ``opm_s_tile``)
jointly with the attention/chunk knobs against the HBM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
OPM_NORM_EPS = 1e-3  # AlphaFold's outer-product-mean mask-norm epsilon
# Default k/s accumulation tile of the Pallas grids when the knob is 0 —
# VMEM-budgeted, deliberately smaller than the XLA legs' default j block
# (ops._DEFAULT_TRI_TILE / _DEFAULT_OPM_TILE = 128, the HBM-visible
# transient the AutoChunk planner models).
DEFAULT_PALLAS_TILE = 64


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def triangle_gate_a(a_lin, ga, mask):
    """Input gating + pair mask of the left triangle operand:
    ``(a_lin * sigmoid(ga)).astype(dt) * mask`` with fp32 sigmoid. On the
    Pallas leg this runs in VMEM per tile; here it is the shared jnp form
    for the XLA leg and the backward recompute (XLA fuses it into the
    consumer einsum — the gated copy is never a standalone HBM tensor)."""
    af = a_lin.astype(jnp.float32) * jax.nn.sigmoid(ga.astype(jnp.float32))
    return af.astype(a_lin.dtype) * mask.astype(a_lin.dtype)[..., None]


# ---------------------------------------------------------------------------
# Triangle multiplicative update — Pallas forward
# ---------------------------------------------------------------------------


def _tri_kernel(a_ref, ga_ref, mk_ref, b_ref, gam_ref, bet_ref, w_ref,
                bo_ref, gl_ref, gb_ref, o_ref, mean_ref, inv_ref, acc_ref,
                *, eps: float, c_actual: int):
    kk = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Input gating + pair mask fused in VMEM (the gated a never hits HBM).
    a = (a_ref[0].astype(jnp.float32)
         * jax.nn.sigmoid(ga_ref[0].astype(jnp.float32)))
    a = a.astype(a_ref.dtype) * mk_ref[0].astype(a_ref.dtype)[..., None]
    b = b_ref[0]                                   # (j_t, k_t, C)
    # o[c, i, j] += sum_k a[i, k, c] * b[j, k, c]: batch over c, contract k.
    acc_ref[...] += jax.lax.dot_general(
        a.transpose(2, 0, 1), b.transpose(2, 0, 1),
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kk == n_k - 1)
    def _epilogue():
        o = acc_ref[...].transpose(1, 2, 0)        # (i_t, j_t, C)
        i_t, j_t, cp = o.shape
        o2 = o.reshape(i_t * j_t, cp)
        if c_actual != cp:
            lane = jax.lax.broadcasted_iota(jnp.int32, o2.shape, 1)
            o2 = jnp.where(lane < c_actual, o2, 0.0)
        cnt = jnp.float32(c_actual)
        mean = jnp.sum(o2, axis=-1, keepdims=True) / cnt
        var = jnp.maximum(jnp.sum(o2 * o2, axis=-1, keepdims=True) / cnt
                          - mean * mean, 0.0)
        inv = jax.lax.rsqrt(var + eps)
        # Padded-C lanes: gamma/beta are zero-padded, so y vanishes there.
        y = ((o2 - mean) * inv * gam_ref[...][0].astype(jnp.float32)
             + bet_ref[...][0].astype(jnp.float32)).astype(o_ref.dtype)
        z = jax.lax.dot_general(
            y, w_ref[...].astype(y.dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) + bo_ref[...][0].astype(jnp.float32)
        gl = (gl_ref[0].reshape(i_t * j_t, -1).astype(jnp.float32)
              + gb_ref[...][0].astype(jnp.float32))
        outv = jax.nn.sigmoid(gl) * z
        o_ref[0] = outv.reshape(i_t, j_t, -1).astype(o_ref.dtype)
        mean_ref[0] = mean.reshape(i_t, j_t)
        inv_ref[0] = inv.reshape(i_t, j_t)


@functools.partial(jax.jit, static_argnames=("eps", "k_tile", "interpret"))
def fused_triangle_pallas(
    a_lin: jax.Array,     # (B, I, K, C) left projection, pre-gate
    ga: jax.Array,        # (B, I, K, C) left gate logits
    mask: jax.Array,      # (B, I, K) pair mask
    b: jax.Array,         # (B, J, K, C) right operand (gated+masked, gathered)
    gamma: jax.Array,     # (C,) output LN
    beta: jax.Array,
    w_out: jax.Array,     # (C, D) output projection
    b_out: jax.Array,     # (D,)
    g_lin: jax.Array,     # (B, I, J, D) output gate logits, pre-bias
    g_bias: jax.Array,    # (D,)
    *,
    eps: float = 1e-5,
    k_tile: int = 0,
    interpret: bool = False,
):
    """Fused triangle multiplicative update (see module docstring).

    Returns (out (B, I, J, D) in g_lin.dtype, mean (B, I, J) fp32,
    inv (B, I, J) fp32) — the stats feed the recompute backward."""
    bsz, i_len, k_len, c = a_lin.shape
    j_len = b.shape[1]
    d = w_out.shape[1]
    dt = a_lin.dtype

    i_t = min(16, _pad_to(i_len, 8))
    j_t = min(128, _pad_to(j_len, 8))
    k_t = min(_pad_to(k_tile or DEFAULT_PALLAS_TILE, 8), _pad_to(k_len, 8))
    ip, jp, kp = _pad_to(i_len, i_t), _pad_to(j_len, j_t), _pad_to(k_len, k_t)
    cp, dp = _pad_to(c, LANE), _pad_to(d, LANE)

    def pad4(x, n1, n2, n3):
        return jnp.pad(x, ((0, 0), (0, n1 - x.shape[1]),
                           (0, n2 - x.shape[2]), (0, n3 - x.shape[3])))

    a_p = pad4(a_lin, ip, kp, cp)
    ga_p = pad4(ga, ip, kp, cp)
    mk_p = jnp.pad(mask, ((0, 0), (0, ip - i_len), (0, kp - k_len)))
    b_p = pad4(b, jp, kp, cp)
    gl_p = pad4(g_lin, ip, jp, dp)
    gam_p = jnp.pad(gamma, (0, cp - c)).reshape(1, cp)
    bet_p = jnp.pad(beta, (0, cp - c)).reshape(1, cp)
    w_p = jnp.pad(w_out, ((0, cp - c), (0, dp - d)))
    bo_p = jnp.pad(b_out, (0, dp - d)).reshape(1, dp)
    gb_p = jnp.pad(g_bias, (0, dp - d)).reshape(1, dp)

    grid = (bsz, ip // i_t, jp // j_t, kp // k_t)
    out, mean, inv = pl.pallas_call(
        functools.partial(_tri_kernel, eps=eps, c_actual=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, i_t, k_t, cp), lambda b_, i, j, k: (b_, i, k, 0)),
            pl.BlockSpec((1, i_t, k_t, cp), lambda b_, i, j, k: (b_, i, k, 0)),
            pl.BlockSpec((1, i_t, k_t), lambda b_, i, j, k: (b_, i, k)),
            pl.BlockSpec((1, j_t, k_t, cp), lambda b_, i, j, k: (b_, j, k, 0)),
            pl.BlockSpec((1, cp), lambda b_, i, j, k: (0, 0)),
            pl.BlockSpec((1, cp), lambda b_, i, j, k: (0, 0)),
            pl.BlockSpec((cp, dp), lambda b_, i, j, k: (0, 0)),
            pl.BlockSpec((1, dp), lambda b_, i, j, k: (0, 0)),
            pl.BlockSpec((1, i_t, j_t, dp), lambda b_, i, j, k: (b_, i, j, 0)),
            pl.BlockSpec((1, dp), lambda b_, i, j, k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, i_t, j_t, dp), lambda b_, i, j, k: (b_, i, j, 0)),
            pl.BlockSpec((1, i_t, j_t), lambda b_, i, j, k: (b_, i, j)),
            pl.BlockSpec((1, i_t, j_t), lambda b_, i, j, k: (b_, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, ip, jp, dp), dt),
            jax.ShapeDtypeStruct((bsz, ip, jp), jnp.float32),
            jax.ShapeDtypeStruct((bsz, ip, jp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((cp, i_t, j_t), jnp.float32)],
        interpret=interpret,
    )(a_p, ga_p, mk_p, b_p, gam_p, bet_p, w_p, bo_p, gl_p, gb_p)
    return (out[:, :i_len, :j_len, :d], mean[:, :i_len, :j_len],
            inv[:, :i_len, :j_len])


# ---------------------------------------------------------------------------
# Triangle — XLA-native leg (non-TPU backends) + recompute backward
# ---------------------------------------------------------------------------


def _tri_block(a, b_blk, gl_blk, gamma, beta, w_out, b_out, g_bias, *, eps):
    """One fused j-block: k-contraction, output LN (fp32 two-pass stats),
    c→d projection, sigmoid output gate. Returns (out, mean, inv)."""
    o = jnp.einsum("bikc,bjkc->bijc", a, b_blk,
                   preferred_element_type=jnp.float32)
    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(o - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = ((o - mean) * inv * gamma.astype(jnp.float32)
         + beta.astype(jnp.float32)).astype(a.dtype)
    z = jnp.einsum("bijc,cd->bijd", y, w_out.astype(a.dtype),
                   preferred_element_type=jnp.float32)
    z = z + b_out.astype(jnp.float32)
    s = jax.nn.sigmoid(gl_blk.astype(jnp.float32)
                       + g_bias.astype(jnp.float32))
    return (s * z).astype(gl_blk.dtype), mean[..., 0], inv[..., 0]


def _split_j(x, axis: int, nb: int, jb: int):
    """Pad axis to nb*jb and move the block axis to the front for lax.scan."""
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, nb * jb - x.shape[axis])
    xp = jnp.pad(x, pad)
    shape = xp.shape[:axis] + (nb, jb) + xp.shape[axis + 1:]
    return jnp.moveaxis(xp.reshape(shape), axis, 0)


def _merge_j(x, axis: int, j_len: int):
    """Inverse of _split_j on the stacked scan output (nb leading)."""
    y = jnp.moveaxis(x, 0, axis)
    shape = y.shape[:axis] + (-1,) + y.shape[axis + 2:]
    y = y.reshape(shape)
    return jax.lax.slice_in_dim(y, 0, j_len, axis=axis)


def fused_triangle_xla(a, b_full, g_lin, gamma, beta, w_out, b_out, g_bias,
                       *, eps: float = 1e-5, j_block: int = 0):
    """XLA-native fused triangle update: lax.scan over j output blocks, the
    LN/projection/gate epilogue fused into each block — the fp32 product
    transient is bounded at (B, I, j_block, C). ``a`` is the gated+masked
    left operand (triangle_gate_a). Returns (out, mean, inv) like the
    kernel."""
    j_len = b_full.shape[1]
    jb = min(j_block or j_len, j_len)
    nb = _ceil_div(j_len, jb)
    if nb <= 1:
        return _tri_block(a, b_full, g_lin, gamma, beta, w_out, b_out,
                          g_bias, eps=eps)
    bs = _split_j(b_full, 1, nb, jb)
    gls = _split_j(g_lin, 2, nb, jb)

    def step(_, xs):
        bb, gl = xs
        return None, _tri_block(a, bb, gl, gamma, beta, w_out, b_out,
                                g_bias, eps=eps)

    _, (outs, means, invs) = jax.lax.scan(step, None, (bs, gls))
    return (_merge_j(outs, 2, j_len), _merge_j(means, 2, j_len),
            _merge_j(invs, 2, j_len))


def triangle_mult_bwd(eps: float, tile: int, res, dout):
    """Recompute backward for ops.fused_triangle_mult: rebuilds the product
    tile-by-tile over j blocks from the saved inputs + per-tile (mean, inv)
    stats — no (B, I, J, C) residual. Returns grads for every diff input."""
    (a_lin, ga, mask, b_full, gamma, beta, w_out, b_out, g_lin, g_bias,
     mean, inv, out) = res
    f32 = jnp.float32
    sig = jax.nn.sigmoid(ga.astype(f32))
    u = (a_lin.astype(f32) * sig).astype(a_lin.dtype)
    a = u * mask.astype(a_lin.dtype)[..., None]
    j_len = b_full.shape[1]
    gam = gamma.astype(f32)

    def block(b_blk, gl_blk, mean_b, inv_b, g_b, out_b):
        o = jnp.einsum("bikc,bjkc->bijc", a, b_blk,
                       preferred_element_type=f32)
        xhat = (o - mean_b[..., None]) * inv_b[..., None]
        y = (xhat * gam + beta.astype(f32)).astype(a.dtype)
        s = jax.nn.sigmoid(gl_blk.astype(f32) + g_bias.astype(f32))
        gf = g_b.astype(f32)
        dz = gf * s
        # Output-gate cotangent from the saved output: g·z·s(1-s) with
        # z = out/s rearranged to g·out·(1-s) — no z recompute, no division.
        dgl = gf * out_b.astype(f32) * (1.0 - s)
        dy = jnp.einsum("bijd,cd->bijc", dz, w_out.astype(f32))
        dw = jnp.einsum("bijc,bijd->cd", y.astype(f32), dz)
        dgamma = jnp.einsum("bijc,bijc->c", dy, xhat)
        dbeta = jnp.sum(dy, axis=(0, 1, 2))
        dbo = jnp.sum(dz, axis=(0, 1, 2))
        dgb = jnp.sum(dgl, axis=(0, 1, 2))
        gg = dy * gam
        do = inv_b[..., None] * (
            gg - jnp.mean(gg, axis=-1, keepdims=True)
            - xhat * jnp.mean(gg * xhat, axis=-1, keepdims=True))
        da = jnp.einsum("bijc,bjkc->bikc", do, b_blk.astype(f32))
        db = jnp.einsum("bijc,bikc->bjkc", do, a.astype(f32))
        return da, db, dgl, dw, dgamma, dbeta, dbo, dgb

    jb = min(tile or j_len, j_len)
    nb = _ceil_div(j_len, jb)
    if nb <= 1:
        (da, db_full, dgl, dw, dgamma, dbeta, dbo, dgb) = block(
            b_full, g_lin, mean, inv, dout, out)
    else:
        bs = _split_j(b_full, 1, nb, jb)
        gls = _split_j(g_lin, 2, nb, jb)
        # Padded-j stats are zero-padded (finite); padded dout rows are zero
        # so every padded contribution vanishes.
        means = _split_j(mean, 2, nb, jb)
        invs = _split_j(inv, 2, nb, jb)
        gs = _split_j(dout, 2, nb, jb)
        outs = _split_j(out, 2, nb, jb)

        def step(carry, xs):
            da_c, dw_c, dga_c, dbe_c, dbo_c, dgb_c = carry
            bb, gl, me, iv, g_b, out_b = xs
            da, db, dgl, dw, dgamma, dbeta, dbo, dgb = block(
                bb, gl, me, iv, g_b, out_b)
            return ((da_c + da, dw_c + dw, dga_c + dgamma, dbe_c + dbeta,
                     dbo_c + dbo, dgb_c + dgb), (db, dgl))

        zeros = (
            jnp.zeros(a.shape, f32), jnp.zeros(w_out.shape, f32),
            jnp.zeros(gamma.shape, f32), jnp.zeros(beta.shape, f32),
            jnp.zeros(b_out.shape, f32), jnp.zeros(g_bias.shape, f32),
        )
        carry, (dbs, dgls) = jax.lax.scan(step, zeros,
                                          (bs, gls, means, invs, gs, outs))
        da, dw, dgamma, dbeta, dbo, dgb = carry
        db_full = _merge_j(dbs, 1, j_len)
        dgl = _merge_j(dgls, 2, j_len)

    # Input-gating adjoints (a = (a_lin * sigmoid(ga)).astype(dt) * mask).
    da_m = da * mask.astype(f32)[..., None]
    da_lin = (da_m * sig).astype(a_lin.dtype)
    dga = (da_m * a_lin.astype(f32) * sig * (1.0 - sig)).astype(ga.dtype)
    dmask = jnp.einsum("bikc,bikc->bik", da, u.astype(f32)).astype(mask.dtype)
    return (da_lin, dga, dmask, db_full.astype(b_full.dtype),
            dgamma.astype(gamma.dtype), dbeta.astype(beta.dtype),
            dw.astype(w_out.dtype), dbo.astype(b_out.dtype),
            dgl.astype(g_lin.dtype), dgb.astype(g_bias.dtype))


# ---------------------------------------------------------------------------
# Outer-product-mean — Pallas forward
# ---------------------------------------------------------------------------


def _opm_kernel(a_ref, b_ref, ma_ref, mb_ref, w_ref, bias_ref, o_ref,
                acc_ref, nrm_ref, *, c: int):
    ss = pl.program_id(3)
    n_s = pl.num_programs(3)

    @pl.when(ss == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        nrm_ref[...] = jnp.zeros_like(nrm_ref)

    a = a_ref[0]                                    # (s_t, i_t*C)
    b = b_ref[0]                                    # (s_t, j_t*C)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # (i_t*C, j_t*C)
    ma = ma_ref[0].astype(jnp.float32)              # (s_t, i_t)
    mb = mb_ref[0].astype(jnp.float32)              # (s_t, j_t)
    j_t = mb.shape[-1]
    nrm_ref[:, :j_t] += jax.lax.dot_general(
        ma, mb, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ss == n_s - 1)
    def _epilogue():
        o = acc_ref[...]
        i_t = o.shape[0] // c
        j_t = o.shape[1] // c
        # (i_t*C, j_t*C) -> (i_t*j_t, C*C) vectorized outer products.
        o4 = o.reshape(i_t, c, j_t, c).transpose(0, 2, 1, 3)
        o2 = o4.reshape(i_t * j_t, c * c)
        norm = nrm_ref[:, :j_t].reshape(i_t * j_t, 1)
        ov = (o2 / (norm + OPM_NORM_EPS)).astype(o_ref.dtype)
        z = jax.lax.dot_general(
            ov, w_ref[...].astype(ov.dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) + bias_ref[...][0].astype(jnp.float32)
        o_ref[0] = z.reshape(i_t, j_t, -1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("s_tile", "interpret"))
def fused_opm_pallas(
    a: jax.Array,        # (B, S, I, C) left projection, masked
    b: jax.Array,        # (B, S, J, C) right projection, masked (gathered)
    mask_a: jax.Array,   # (B, S, I)
    mask_b: jax.Array,   # (B, S, J)
    w: jax.Array,        # (C*C, D)
    bias: jax.Array,     # (D,)
    *,
    s_tile: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """Fused outer-product-mean (see module docstring). Returns
    (B, I, J, D) in a.dtype."""
    bsz, s_len, i_len, c = a.shape
    j_len = b.shape[2]
    d = w.shape[1]
    dt = a.dtype

    i_t = min(16, _pad_to(i_len, 8))
    j_t = min(16, _pad_to(j_len, 8))
    s_t = min(_pad_to(s_tile or DEFAULT_PALLAS_TILE, 8), _pad_to(s_len, 8))
    ip, jp = _pad_to(i_len, i_t), _pad_to(j_len, j_t)
    sp = _pad_to(s_len, s_t)
    dp = _pad_to(d, LANE)

    def pad_proj(x, n_r):
        xp = jnp.pad(x, ((0, 0), (0, sp - s_len), (0, n_r - x.shape[2]),
                         (0, 0)))
        return xp.reshape(bsz, sp, n_r * c)        # free reshape, lane-merged

    a_p = pad_proj(a, ip)
    b_p = pad_proj(b, jp)
    ma_p = jnp.pad(mask_a, ((0, 0), (0, sp - s_len), (0, ip - i_len)))
    mb_p = jnp.pad(mask_b, ((0, 0), (0, sp - s_len), (0, jp - j_len)))
    w_p = jnp.pad(w, ((0, 0), (0, dp - d)))
    bias_p = jnp.pad(bias, (0, dp - d)).reshape(1, dp)

    grid = (bsz, ip // i_t, jp // j_t, sp // s_t)
    out = pl.pallas_call(
        functools.partial(_opm_kernel, c=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s_t, i_t * c), lambda b_, i, j, s: (b_, s, i)),
            pl.BlockSpec((1, s_t, j_t * c), lambda b_, i, j, s: (b_, s, j)),
            pl.BlockSpec((1, s_t, i_t), lambda b_, i, j, s: (b_, s, i)),
            pl.BlockSpec((1, s_t, j_t), lambda b_, i, j, s: (b_, s, j)),
            pl.BlockSpec((c * c, dp), lambda b_, i, j, s: (0, 0)),
            pl.BlockSpec((1, dp), lambda b_, i, j, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, i_t, j_t, dp),
                               lambda b_, i, j, s: (b_, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, ip, jp, dp), dt),
        scratch_shapes=[
            pltpu.VMEM((i_t * c, j_t * c), jnp.float32),
            pltpu.VMEM((i_t, max(j_t, LANE)), jnp.float32),
        ],
        interpret=interpret,
    )(a_p, b_p, ma_p, mb_p, w_p, bias_p)
    return out[:, :i_len, :j_len, :d]


# ---------------------------------------------------------------------------
# OPM — XLA-native leg + recompute backward
# ---------------------------------------------------------------------------


def _opm_block(a, b_blk, mask_a, mask_b_blk, w, bias):
    """One fused OPM j-block on the XLA leg: the mask-norm divides by a
    per-(i, j) scalar and the c²→d projection is linear, so the contraction
    reassociates — ``(Σ_s a⊗b / denom) @ w == (a · (b · w3)) / denom`` with
    ``w3 = w.reshape(c, c, d)``. The (B, I, J, C, C) outer-product tensor is
    never formed AT ALL on this leg (the Pallas kernel accumulates it
    per-tile in VMEM instead); the largest transient is the
    (B, S, j_block, C, D) half-contraction ``h``, linear in j_block. The
    reassociated GEMMs are also the layouts XLA:CPU runs ~5x faster than
    the outer-product einsum — this is where the fused path's wall-time win
    over the materialized baseline comes from off-TPU."""
    f32 = jnp.float32
    c = a.shape[-1]
    w3 = w.reshape(c, c, w.shape[-1]).astype(a.dtype)
    h = jnp.einsum("bsjy,xyd->bsjxd", b_blk, w3,
                   preferred_element_type=f32)
    numer = jnp.einsum("bsix,bsjxd->bijd", a, h,
                       preferred_element_type=f32)
    norm = jnp.einsum("bsi,bsj->bij", mask_a.astype(f32),
                      mask_b_blk.astype(f32))
    out = numer / (norm[..., None] + OPM_NORM_EPS) + bias.astype(f32)
    return out.astype(a.dtype)


def fused_opm_xla(a, b_full, mask_a, mask_b, w, bias, *, j_block: int = 0):
    """XLA-native fused OPM: lax.scan over j output blocks with the
    normalization + projection fused into each block — the fp32
    (B, I, j_block, C, C) transient never reaches full-J size."""
    j_len = b_full.shape[2]
    jb = min(j_block or j_len, j_len)
    nb = _ceil_div(j_len, jb)
    if nb <= 1:
        return _opm_block(a, b_full, mask_a, mask_b, w, bias)
    bs = _split_j(b_full, 2, nb, jb)
    mbs = _split_j(mask_b, 2, nb, jb)

    def step(_, xs):
        bb, mb = xs
        return None, _opm_block(a, bb, mask_a, mb, w, bias)

    _, outs = jax.lax.scan(step, None, (bs, mbs))
    return _merge_j(outs, 2, j_len)


def opm_bwd(tile: int, res, dout):
    """Recompute backward for ops.fused_outer_product_mean: per j block,
    push the cotangent through the reassociated contraction (see
    _opm_block) — no (B, I, J, C, C) tensor is ever formed; the transients
    are the (B, S, ·, C, D) half-contractions, j-block bounded. The saved
    output gives the mask-norm cotangent directly
    (Σ_x ov·(g@wᵀ) = Σ_d (out - bias)·g), skipping a c²-wide reduction."""
    a, b_full, mask_a, mask_b, w, bias, out = res
    f32 = jnp.float32
    j_len = b_full.shape[2]
    c = a.shape[-1]
    maf = mask_a.astype(f32)
    w3 = w.reshape(c, c, w.shape[-1]).astype(a.dtype)

    def block(b_blk, mb_blk, g_b, out_b):
        # Natural adjoint of the reassociated forward: recompute the right
        # half-contraction h, then da via (u, h) and db/dw via the shared
        # dh = a·u half-contraction — two (s·r·j_block·c·d)-MAC GEMMs total,
        # never a (i, j, c, c) tensor.
        gf = g_b.astype(f32)
        norm = jnp.einsum("bsi,bsj->bij", maf, mb_blk.astype(f32))
        denom = norm + OPM_NORM_EPS
        u = gf / denom[..., None]
        h = jnp.einsum("bsjy,xyd->bsjxd", b_blk, w3,
                       preferred_element_type=f32)
        da = jnp.einsum("bijd,bsjxd->bsix", u, h)
        dh = jnp.einsum("bsix,bijd->bsjxd", a.astype(f32), u)
        db = jnp.einsum("bsjxd,xyd->bsjy", dh, w3.astype(f32))
        dw = jnp.einsum("bsjy,bsjxd->xyd", b_blk.astype(f32), dh
                        ).reshape(c * c, -1)
        dnorm = -jnp.einsum("bijd,bijd->bij", out_b.astype(f32)
                            - bias.astype(f32), gf) / denom
        dma = jnp.einsum("bij,bsj->bsi", dnorm, mb_blk.astype(f32))
        dmb = jnp.einsum("bij,bsi->bsj", dnorm, maf)
        dbias = jnp.sum(gf, axis=(0, 1, 2))
        return da, db, dma, dmb, dw, dbias

    jb = min(tile or j_len, j_len)
    nb = _ceil_div(j_len, jb)
    if nb <= 1:
        da, db_full, dma, dmb, dw, dbias = block(b_full, mask_b, dout, out)
    else:
        bs = _split_j(b_full, 2, nb, jb)
        mbs = _split_j(mask_b, 2, nb, jb)
        gs = _split_j(dout, 2, nb, jb)
        outs = _split_j(out, 2, nb, jb)

        def step(carry, xs):
            da_c, dma_c, dw_c, dbias_c = carry
            bb, mb, g_b, out_b = xs
            da, db, dma, dmb, dw, dbias = block(bb, mb, g_b, out_b)
            return ((da_c + da, dma_c + dma, dw_c + dw, dbias_c + dbias),
                    (db, dmb))

        zeros = (jnp.zeros(a.shape, f32), jnp.zeros(mask_a.shape, f32),
                 jnp.zeros(w.shape, f32), jnp.zeros(bias.shape, f32))
        carry, (dbs, dmbs) = jax.lax.scan(step, zeros, (bs, mbs, gs, outs))
        da, dma, dw, dbias = carry
        db_full = _merge_j(dbs, 2, j_len)
        dmb = _merge_j(dmbs, 2, j_len)

    return (da.astype(a.dtype), db_full.astype(b_full.dtype),
            dma.astype(mask_a.dtype), dmb.astype(mask_b.dtype),
            dw.astype(w.dtype), dbias.astype(bias.dtype))
