"""AlphaFold model configs (paper Table I): Initial Training and Fine-tuning,
plus the reduced smoke/benchmark variants used on CPU."""
from __future__ import annotations

from dataclasses import replace

from repro.core.alphafold import AlphaFoldConfig
from repro.core.evoformer import EvoformerConfig
from repro.core.structure import StructureConfig

# Full AlphaFold-2 model: 48 Evoformer blocks, Hm=256, Hz=128 (~93M params).
FULL = AlphaFoldConfig(
    evoformer=EvoformerConfig(d_msa=256, d_pair=128, msa_heads=8, pair_heads=4,
                              head_dim=32, opm_dim=32, tri_mult_dim=128,
                              n_blocks=48),
    structure=StructureConfig(c_s=384, c_z=128, n_heads=12, c_hidden=16,
                              n_qk_points=4, n_v_points=8, n_iterations=8),
    n_recycle=3,
)

# Paper Table I shapes.
INITIAL_TRAINING = {"n_res": 256, "n_seq": 128, "batch": 128}
FINE_TUNING = {"n_res": 384, "n_seq": 512, "batch": 128}

# ~100M-param config trainable on CPU for the end-to-end example: same family,
# fewer blocks / smaller MSA stack.
MINI = AlphaFoldConfig(
    evoformer=EvoformerConfig(d_msa=64, d_pair=32, msa_heads=4, pair_heads=2,
                              head_dim=16, opm_dim=16, tri_mult_dim=32,
                              n_blocks=4),
    structure=StructureConfig(c_s=64, c_z=32, n_heads=4, c_hidden=8,
                              n_qk_points=4, n_v_points=4, n_iterations=4),
    n_recycle=1,
)

SMOKE = AlphaFoldConfig(
    evoformer=EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2,
                              head_dim=8, opm_dim=8, tri_mult_dim=16,
                              n_blocks=2),
    structure=StructureConfig(c_s=32, c_z=16, n_heads=4, c_hidden=8,
                              n_qk_points=2, n_v_points=2, n_iterations=2),
    n_recycle=1,
)
