"""Paper Fig. 8 — Fused Softmax.

Compares the unfused op chain (scale, +bias, +mask, softmax as four separate
dispatches, materializing three intermediates — the PyTorch-native situation
the paper measures) against the fused kernel, across the paper's problem-size
range (many short rows). Also certifies kernel == oracle and reports the
modeled HBM-traffic ratio (the quantity that determines the TPU speedup,
since these ops are bandwidth-bound).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.kernels import ops, ref

# (n_rows_total, row_len): paper sweeps attention shapes with small hidden.
SIZES = [(2048, 128), (8192, 128), (2048, 256), (8192, 256), (2048, 512),
         (4096, 1024)]


def run():
    for rows, cols in SIZES:
        n, h, r = 8, 4, rows // 32
        c = cols
        x = jax.random.normal(jax.random.PRNGKey(0), (n, h, r, c),
                              jnp.bfloat16)
        bias = jax.random.normal(jax.random.PRNGKey(1), (h, r, c),
                                 jnp.bfloat16)
        mask = jnp.where(
            jax.random.bernoulli(jax.random.PRNGKey(2), 0.9, (n, c)),
            0.0, -1e9).astype(jnp.float32)

        # unfused: four separate dispatches (kernel-launch + 3 intermediates)
        scale_f = jax.jit(lambda x: x * 0.125)
        bias_f = jax.jit(lambda x, b: x + b[None])
        mask_f = jax.jit(lambda x, m: x + m[:, None, None, :].astype(x.dtype))
        soft_f = jax.jit(lambda x: jax.nn.softmax(
            x.astype(jnp.float32), axis=-1).astype(x.dtype))

        def unfused(x, bias, mask):
            return soft_f(mask_f(bias_f(scale_f(x), bias), mask))

        # Wall-clock "fused" path: the single-dispatch oracle (XLA fuses the
        # whole chain) — the CPU stand-in for the TPU kernel. The Pallas
        # kernel itself runs interpret-mode on CPU (pure-Python per grid
        # cell), so timing it here would measure the interpreter; it is
        # instead verified for exactness below.
        fused = jax.jit(lambda x, b, m: ref.softmax_ref(x, b[None], m, 0.125))

        got_kernel = ops.fused_softmax(x, bias, mask, 0.125)
        want = ref.softmax_ref(x, bias[None], mask, 0.125)
        np.testing.assert_allclose(np.asarray(got_kernel, np.float32),
                                   np.asarray(want, np.float32), atol=3e-2)

        t_un = time_fn(unfused, x, bias, mask, iters=10)
        t_fu = time_fn(fused, x, bias, mask, iters=10)
        elems = n * h * r * c
        # HBM traffic: unfused reads/writes x 4x (plus bias/mask); fused 1x.
        bytes_unfused = elems * 2 * (2 * 4) + bias.size * 2 + mask.size * 4
        bytes_fused = elems * 2 * 2 + bias.size * 2 + mask.size * 4
        csv_row(f"softmax_{rows}x{cols}_unfused", t_un, "4 dispatches")
        csv_row(f"softmax_{rows}x{cols}_fused", t_fu,
                f"speedup={t_un / t_fu:.2f}x "
                f"hbm_ratio={bytes_unfused / bytes_fused:.2f}x "
                f"pallas_kernel_allclose=ok")


if __name__ == "__main__":
    run()
