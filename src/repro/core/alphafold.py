"""End-to-end AlphaFold-2 model: embedders, recycling, Evoformer trunk (DAP-
parallelizable), structure module, and training heads.

Chunking: ``alphafold_forward`` resolves the Evoformer chunk knobs through the
AutoChunk planner (repro.memory.autochunk) at trace time — the largest
settings whose modeled activation memory fits the per-chip HBM budget, no
chunking when everything fits. Hand-set nonzero knobs and
``evoformer.auto_chunk=False`` opt out.

Execution policy: the ``dist`` backend, the HBM budget, and AutoChunk knob
overrides default to the context-local ExecutionPlan
(``repro.exec.plan.current_plan()``) — ``with use_plan(plan):`` around a
call (or the ``repro.exec.session.FastFold`` facade, which binds the plan
once) steers them without kwarg plumbing. Explicit ``dist=`` /
``hbm_budget=`` arguments still win for composition (the DAP drivers hand
shard_map-local backends directly)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.exec.plan import current_plan
from repro.core.evoformer import (
    EvoformerConfig,
    evoformer_stack,
    init_evoformer_stack,
)
from repro.core.losses import N_DIST_BINS, N_MSA_TOK, alphafold_loss
from repro.core.structure import (
    StructureConfig,
    init_structure_module,
    structure_module,
)
from repro.layers.norms import init_layer_norm, layer_norm
from repro.layers.params import Params, dense, init_dense
from repro.memory.autochunk import resolve_evoformer_config

N_AA = 21
RELPOS_K = 32


@dataclass(frozen=True)
class AlphaFoldConfig:
    evoformer: EvoformerConfig = field(default_factory=EvoformerConfig)
    structure: StructureConfig = field(default_factory=StructureConfig)
    n_recycle: int = 3          # extra passes (total passes = n_recycle + 1)
    recycle_bins: int = 15
    compute_dtype: Any = jnp.bfloat16

    @property
    def d_msa(self):
        return self.evoformer.d_msa

    @property
    def d_pair(self):
        return self.evoformer.d_pair


def init_alphafold(key, cfg: AlphaFoldConfig) -> Params:
    ks = iter(jax.random.split(key, 16))
    d_m, d_z = cfg.d_msa, cfg.d_pair
    return {
        "msa_embed": init_dense(next(ks), N_MSA_TOK, d_m, bias=True),
        "target_embed_m": init_dense(next(ks), N_AA, d_m, bias=True),
        "left_embed": init_dense(next(ks), N_AA, d_z, bias=True),
        "right_embed": init_dense(next(ks), N_AA, d_z, bias=True),
        "relpos_embed": init_dense(next(ks), 2 * RELPOS_K + 1, d_z, bias=True),
        "recycle": {
            "ln_m": init_layer_norm(d_m),
            "ln_z": init_layer_norm(d_z),
            "dist_embed": init_dense(next(ks), cfg.recycle_bins, d_z, bias=True),
        },
        "evoformer": init_evoformer_stack(next(ks), cfg.evoformer),
        "single_proj": init_dense(next(ks), d_m, cfg.structure.c_s, bias=True),
        "structure": init_structure_module(next(ks), cfg.structure),
        "msa_head": init_dense(next(ks), d_m, N_MSA_TOK, bias=True),
        "dist_head": init_dense(next(ks), d_z, N_DIST_BINS, bias=True),
    }


def embed_inputs(params, batch, cfg: AlphaFoldConfig):
    """batch: dict with msa (B,s,r) int, aatype (B,r) int, residue_index (B,r)."""
    dt = cfg.compute_dtype
    msa_oh = jax.nn.one_hot(batch["msa"], N_MSA_TOK, dtype=dt)
    aa_oh = jax.nn.one_hot(batch["aatype"], N_AA, dtype=dt)
    msa_rep = dense(params["msa_embed"], msa_oh)
    msa_rep = msa_rep + dense(params["target_embed_m"], aa_oh)[:, None]
    left = dense(params["left_embed"], aa_oh)
    right = dense(params["right_embed"], aa_oh)
    pair = left[:, :, None, :] + right[:, None, :, :]
    rel = jnp.clip(
        batch["residue_index"][:, :, None] - batch["residue_index"][:, None, :],
        -RELPOS_K, RELPOS_K,
    ) + RELPOS_K
    pair = pair + dense(params["relpos_embed"],
                        jax.nn.one_hot(rel, 2 * RELPOS_K + 1, dtype=dt))
    return msa_rep, pair


def embed_recycle(params, msa, pair, prev, cfg: AlphaFoldConfig):
    """Add recycled features (Jumper et al. §1.10): LN'ed previous reps and a
    binned distance embedding of the previous predicted CB/CA positions."""
    prev_msa_row, prev_pair, prev_pos = prev
    msa = msa.at[:, 0].add(
        layer_norm(params["recycle"]["ln_m"], prev_msa_row).astype(msa.dtype)
    )
    pair = pair + layer_norm(params["recycle"]["ln_z"], prev_pair).astype(pair.dtype)
    d = jnp.linalg.norm(
        prev_pos[:, :, None] - prev_pos[:, None] + 1e-8, axis=-1
    )
    edges = jnp.linspace(3.375, 21.375, cfg.recycle_bins - 1)
    bins = jnp.sum(d[..., None] > edges, axis=-1)
    pair = pair + dense(
        params["recycle"]["dist_embed"],
        jax.nn.one_hot(bins, cfg.recycle_bins, dtype=pair.dtype),
    )
    return msa, pair


def alphafold_iteration(params, batch, prev, cfg: AlphaFoldConfig, *,
                        dist=None, rng=None, train=False):
    """One recycling iteration: embed -> Evoformer -> structure + heads.

    Under DAP the caller passes already-sharded batch tensors and a dist
    backend; embedding/heads/structure are element-wise or replicated-safe.
    ``dist=None`` resolves the current plan's ParallelPolicy.
    """
    if dist is None:
        dist = current_plan().parallel.make_dist()
    dt = cfg.compute_dtype
    msa, pair = embed_inputs(params, batch, cfg)
    msa, pair = embed_recycle(params, msa, pair, prev, cfg)
    msa = msa.astype(dt)
    pair = pair.astype(dt)

    seq_mask = batch["seq_mask"]
    pair_mask = seq_mask[:, :, None] * seq_mask[:, None, :]
    msa, pair = evoformer_stack(
        params["evoformer"], msa, pair, batch["msa_mask"], seq_mask, pair_mask,
        dist=dist, cfg=cfg.evoformer, rng=rng, train=train,
    )

    single = dense(params["single_proj"], msa[:, 0].astype(jnp.float32))
    coords, frames, traj = structure_module(
        params["structure"], single, pair.astype(jnp.float32), seq_mask,
        cfg.structure,
    )
    return {
        "msa": msa,
        "pair": pair,
        "coords": coords,
        "frames": frames,
        "traj": traj,
        "msa_logits": dense(params["msa_head"], msa.astype(jnp.float32)),
        "distogram_logits": dense(params["dist_head"], pair.astype(jnp.float32)),
    }


def alphafold_forward(params, batch, cfg: AlphaFoldConfig, *,
                      n_recycle: int | jax.Array | None = None,
                      dist=None, rng=None, train=False,
                      hbm_budget: int | None = None):
    """Full forward with recycling. Pre-final iterations run under
    stop_gradient (AlphaFold training recipe); the number of recycles can be a
    traced scalar (sampled per-batch during training, fixed 3 at inference).

    ``hbm_budget`` overrides the per-chip HBM budget the AutoChunk planner
    resolves chunk knobs against (default: the current plan's
    MemoryPolicy.hbm_budget, else launch.mesh.HBM_BYTES). ``dist=None``
    resolves the current plan's ParallelPolicy; the plan's MemoryPolicy knob
    overrides are applied to the Evoformer config before planning."""
    plan = current_plan()
    if dist is None:
        dist = plan.parallel.make_dist()
    evo_cfg = plan.memory.apply(cfg.evoformer)
    b, s, r = batch["msa"].shape
    # AutoChunk (trace-time, static shapes): fill chunk knobs left at 0 from
    # the HBM budget instead of hand-set constants. budget_bytes=None lets
    # the planner resolve the plan's MemoryPolicy budget itself (one path).
    evo_cfg = resolve_evoformer_config(
        evo_cfg, batch=b, n_seq=s, n_res=r,
        dap=getattr(dist, "axis_size", 1), budget_bytes=hbm_budget)
    if evo_cfg is not cfg.evoformer:
        cfg = dataclasses.replace(cfg, evoformer=evo_cfg)
    d_m, d_z = cfg.d_msa, cfg.d_pair
    if n_recycle is None:
        n_recycle = cfg.n_recycle
    prev = (
        jnp.zeros((b, r, d_m), jnp.float32),
        jnp.zeros((b, r, r, d_z), jnp.float32),
        jnp.zeros((b, r, 3), jnp.float32),
    )

    def body(i, prev):
        out = alphafold_iteration(params, batch, prev, cfg, dist=dist,
                                  rng=rng, train=train)
        return (out["msa"][:, 0].astype(jnp.float32),
                out["pair"].astype(jnp.float32), out["coords"])

    prev = jax.lax.stop_gradient(
        jax.lax.fori_loop(0, n_recycle, body, prev)
    )
    return alphafold_iteration(params, batch, prev, cfg, dist=dist, rng=rng,
                               train=train)


def alphafold_train_loss(params, batch, cfg: AlphaFoldConfig, rng=None,
                         n_recycle=None, dist=None):
    out = alphafold_forward(params, batch, cfg, n_recycle=n_recycle, dist=dist,
                            rng=rng, train=True)
    return alphafold_loss(out, batch)
