"""Parameter initialization / application helpers (pure-JAX module system).

Parameters are nested dicts of jnp arrays. Every layer exposes
``init_<layer>(key, ...) -> params`` and a pure apply function. Stacked-layer
models vmap the init over a leading layer axis and scan the apply — this keeps
the lowered HLO small enough to compile 62-layer models quickly and is what
lets the dry-run cover the full assigned configs.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

Params = dict


def trunc_normal(key, shape, scale: float, dtype=jnp.float32) -> jax.Array:
    """Truncated-normal (±2σ) fan-in init, AlphaFold/LLM standard."""
    std = scale / max(1.0, math.sqrt(shape[0] if len(shape) >= 2 else 1))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def init_dense(
    key,
    d_in: int,
    d_out: int,
    *,
    bias: bool = True,
    scale: float = 1.0,
    zero_init: bool = False,
    dtype=jnp.float32,
) -> Params:
    p = {}
    if zero_init:
        p["w"] = jnp.zeros((d_in, d_out), dtype)
    else:
        p["w"] = trunc_normal(key, (d_in, d_out), scale, dtype)
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array, compute_dtype=None) -> jax.Array:
    dt = compute_dtype or x.dtype
    y = jnp.einsum("...i,io->...o", x.astype(dt), p["w"].astype(dt))
    if "b" in p:
        y = y + p["b"].astype(dt)
    return y


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: Params, ids: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    return jnp.take(p["table"].astype(compute_dtype), ids, axis=0)


def split_keys(key, n: int) -> Sequence[jax.Array]:
    return jax.random.split(key, n)


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def cast_tree(params, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params,
    )
