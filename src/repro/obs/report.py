"""Aggregation over an obs event stream + the report renderer.

Pure-python passes over the stable event schema (``repro/obs/events.py``):

  aggregate(events)   span percentiles (p50/p95/p99) + per-span self-time,
                      counter totals, gauge stats + occupancy histograms,
                      request lifecycle tallies + queued->done latency
                      percentiles, train-step stats, jit-entry/cache-miss
                      census, merged run metadata.
  reconcile(events)   lifecycle invariant check: every queued request ends
                      in exactly one terminal phase (done|failed), no
                      terminal without a queued, no post-terminal events.
  hardware_efficiency(agg)
                      cross-references measured per-token prefill/decode
                      time against the roofline model's hardware constants
                      (launch/mesh.py: peak FLOP/s + HBM bandwidth) using
                      the model facts the engine put in its ``meta`` event
                      — prints the fraction of roofline each phase
                      achieves. The modeled floor is per *chip* (TPU v5e);
                      on a CPU dev box the fraction is honest and tiny.
  render_report(events)
                      the ``python -m repro.obs report`` body.

Only ``hardware_efficiency`` touches jax-adjacent code (a lazy import of
the mesh constants); everything else runs anywhere.
"""
from __future__ import annotations

from repro.obs.events import TERMINAL_PHASES, validate_events  # noqa: F401

_QS = (0.5, 0.95, 0.99)


def quantiles(xs, qs=_QS) -> dict[str, float]:
    """Nearest-rank percentiles, keyed 'p50'/'p95'/'p99'."""
    if not xs:
        return {f"p{int(q * 100)}": 0.0 for q in qs}
    s = sorted(xs)
    out = {}
    for q in qs:
        idx = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
        out[f"p{int(q * 100)}"] = float(s[idx])
    return out


def aggregate(events: list[dict]) -> dict:
    spans: dict[str, dict] = {}
    child_ns: dict[int, float] = {}       # parent span_id -> sum(child dur)
    span_rows: list[dict] = []
    counters: dict[str, float] = {}
    gauges: dict[str, list[float]] = {}
    requests: dict[str, int] = {}
    req_t: dict[int, dict[str, float]] = {}   # uid -> phase -> first t_ns
    prompt_tokens = 0
    train_durs: list[float] = []
    train_skips = 0.0
    train_tokens = 0.0
    last_metrics: dict = {}
    jit: dict[str, dict] = {}
    meta: dict = {}
    defs: dict[str, object] = {}

    for ev in events:
        kind = ev.get("kind")
        if kind == "span":
            span_rows.append(ev)
            if ev.get("parent_id") is not None:
                child_ns[ev["parent_id"]] = (
                    child_ns.get(ev["parent_id"], 0.0) + ev["dur_ns"])
        elif kind == "counter":
            counters[ev["name"]] = ev["value"]
        elif kind == "gauge":
            gauges.setdefault(ev["name"], []).append(float(ev["value"]))
        elif kind == "request":
            phase = ev["name"]
            requests[phase] = requests.get(phase, 0) + 1
            uid = ev.get("uid")
            if uid is not None:
                req_t.setdefault(uid, {}).setdefault(phase, ev["t_ns"])
            if phase == "admitted":
                prompt_tokens += int(ev.get("attrs", {}).get(
                    "prompt_len", 0))
        elif kind == "train_step":
            train_durs.append(float(ev["dur_ns"]))
            m = ev.get("metrics", {})
            last_metrics = m
            train_skips += float(m.get("nonfinite_skips", 0.0) or 0.0)
            train_tokens += float(ev.get("tokens") or 0.0)
        elif kind == "jit_entry":
            site = jit.setdefault(ev["name"], {"calls": 0, "misses": 0,
                                               "keys": set()})
            site["calls"] += 1
            site["keys"].add(ev["key"])
            if ev["cache"] == "miss":
                site["misses"] += 1
        elif kind == "meta":
            meta.update(ev.get("attrs", {}))
        elif kind == "def":
            defs[ev["name"]] = ev.get("value")

    for ev in span_rows:
        name = ev["name"]
        s = spans.setdefault(name, {"count": 0, "total_ns": 0.0,
                                    "self_ns": 0.0, "exec_ns": 0.0,
                                    "dispatch_ns": 0.0, "errors": 0,
                                    "durs": []})
        s["count"] += 1
        s["total_ns"] += ev["dur_ns"]
        s["self_ns"] += ev["dur_ns"] - child_ns.get(ev["span_id"], 0.0)
        s["durs"].append(float(ev["dur_ns"]))
        attrs = ev.get("attrs") or {}
        # jax-timed leaf spans: device-execute vs host-dispatch (the first
        # dispatch on a cold jit cache is the compile cost)
        s["exec_ns"] += float(attrs.get("block_ns", 0.0))
        s["dispatch_ns"] += float(attrs.get("dispatch_ns", 0.0))
        if ev.get("status") == "error":
            s["errors"] += 1
    for s in spans.values():
        s.update({k + "_ns": v for k, v in quantiles(s.pop("durs")).items()})

    latencies_ms = [
        (t["done"] - t["queued"]) / 1e6
        for t in req_t.values() if "done" in t and "queued" in t]
    wait_ms = [
        (t["admitted"] - t["queued"]) / 1e6
        for t in req_t.values() if "admitted" in t and "queued" in t]

    gauge_stats = {
        name: {"n": len(vals), "mean": sum(vals) / len(vals),
               "min": min(vals), "max": max(vals),
               "hist": _int_hist(vals)}
        for name, vals in gauges.items()}

    return {
        "spans": spans,
        "counters": counters,
        "gauges": gauge_stats,
        "requests": {
            "phases": requests,
            "prompt_tokens": prompt_tokens,
            "latency_ms": quantiles(latencies_ms),
            "wait_ms": quantiles(wait_ms),
            "n_latencies": len(latencies_ms),
        },
        "train": {
            "steps": len(train_durs),
            "dispatch_ms": quantiles([d / 1e6 for d in train_durs]),
            "nonfinite_skips": train_skips,
            "tokens": train_tokens,
            "last_metrics": last_metrics,
        },
        "jit": {site: {"calls": d["calls"], "misses": d["misses"],
                       "distinct_keys": len(d["keys"])}
                for site, d in jit.items()},
        "meta": meta,
        "defs": defs,
    }


def _int_hist(vals: list[float]) -> dict[str, int]:
    """Occupancy-style histogram: integer-valued gauges bucket exactly."""
    hist: dict[str, int] = {}
    for v in vals:
        key = str(int(v)) if float(v).is_integer() else f"{v:.3g}"
        hist[key] = hist.get(key, 0) + 1
    return dict(sorted(hist.items(), key=lambda kv: _hist_key(kv[0])))


def _hist_key(k: str) -> float:
    try:
        return float(k)
    except ValueError:
        return float("inf")


def reconcile(events: list[dict]) -> list[str]:
    """Lifecycle invariant violations (empty = every request accounted
    for): each queued uid reaches EXACTLY one terminal phase, terminals
    have a queued, and nothing happens to a uid after its terminal."""
    problems: list[str] = []
    queued: set[int] = set()
    terminal: dict[int, str] = {}
    for ev in events:
        if ev.get("kind") != "request":
            continue
        uid, phase = ev.get("uid"), ev.get("name")
        if uid is None:
            if phase != "rejected":
                problems.append(f"request event {phase!r} without a uid")
            continue
        if uid in terminal:
            problems.append(
                f"uid {uid}: {phase!r} after terminal {terminal[uid]!r}")
            continue
        if phase == "queued":
            queued.add(uid)
        elif phase in TERMINAL_PHASES:
            if uid not in queued:
                problems.append(f"uid {uid}: terminal {phase!r} without "
                                "a queued event")
            terminal[uid] = phase
    for uid in sorted(queued - set(terminal)):
        problems.append(f"uid {uid}: queued but never reached a terminal "
                        "phase")
    return problems


def hardware_efficiency(agg: dict) -> dict:
    """Measured-vs-roofline per phase. Needs the engine ``meta`` facts
    (param_count/param_bytes/cache_row_bytes); returns {} without them."""
    meta = agg["meta"]
    needed = ("param_count", "param_bytes", "cache_row_bytes")
    if not all(k in meta for k in needed):
        return {}
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16  # lazy: jax import

    param_count = float(meta["param_count"])
    param_bytes = float(meta["param_bytes"])
    row_bytes = float(meta["cache_row_bytes"])
    out: dict[str, dict] = {}

    # Decode: each emitted token costs ~2*params FLOPs and must stream the
    # weights + its KV row from HBM (batching amortizes the weight stream
    # across the group — this floor assumes perfect amortization at the
    # mean measured batch, so the fraction is an upper bound on headroom).
    tokens = agg["counters"].get("tokens_decoded", 0.0)
    dec = agg["spans"].get("decode")
    if dec and tokens:
        batch = max(1.0, tokens / max(1, dec["count"]))
        # Execute-side time (block_ns) when the spans carry the jax-timed
        # split — compile cost lives in dispatch_ns and must not be billed
        # against the hardware; fall back to wall time otherwise.
        measured_s = (dec["exec_ns"] or dec["total_ns"]) / 1e9 / tokens
        roofline_s = max(2.0 * param_count / PEAK_FLOPS_BF16,
                         (param_bytes / batch + row_bytes) / HBM_BW)
        out["decode"] = _phase(measured_s, roofline_s, tokens)

    # Prefill: 2*params FLOPs per prompt token; one weight stream per call.
    pre = agg["spans"].get("prefill")
    p_tokens = agg["requests"]["prompt_tokens"]
    if pre and p_tokens:
        measured_s = (pre["exec_ns"] or pre["total_ns"]) / 1e9 / p_tokens
        roofline_s = max(2.0 * param_count / PEAK_FLOPS_BF16,
                         param_bytes / max(1, p_tokens / pre["count"])
                         / HBM_BW)
        out["prefill"] = _phase(measured_s, roofline_s, p_tokens)
    return out


def _phase(measured_s: float, roofline_s: float, tokens: float) -> dict:
    return {
        "tokens": tokens,
        "measured_us_per_token": measured_s * 1e6,
        "roofline_us_per_token": roofline_s * 1e6,
        "efficiency": roofline_s / measured_s if measured_s > 0 else 0.0,
    }


def render_report(events: list[dict]) -> str:
    agg = aggregate(events)
    lines = [f"obs report: {len(events)} events"]
    if agg["meta"]:
        facts = ", ".join(f"{k}={agg['meta'][k]}"
                          for k in sorted(agg["meta"]) if k != "plan")
        lines.append(f"  meta: {facts}")
    if agg["spans"]:
        lines.append("  spans (count / total ms / self ms / p50 / p95 / "
                     "p99 ms):")
        for name, s in sorted(agg["spans"].items()):
            split = ""
            if s["exec_ns"]:
                split = (f"  [dispatch {s['dispatch_ns'] / 1e6:.2f} / "
                         f"execute {s['exec_ns'] / 1e6:.2f} ms]")
            lines.append(
                f"    {name:22s} {s['count']:6d}  "
                f"{s['total_ns'] / 1e6:9.2f} {s['self_ns'] / 1e6:9.2f}  "
                f"{s['p50_ns'] / 1e6:8.3f} {s['p95_ns'] / 1e6:8.3f} "
                f"{s['p99_ns'] / 1e6:8.3f}" + split
                + (f"  ({s['errors']} error)" if s["errors"] else ""))
    req = agg["requests"]
    if req["phases"]:
        phases = ", ".join(f"{k}={v}"
                           for k, v in sorted(req["phases"].items()))
        lines.append(f"  requests: {phases}")
        lat = req["latency_ms"]
        lines.append(
            f"  latency queued->done (ms): p50={lat['p50']:.2f} "
            f"p95={lat['p95']:.2f} p99={lat['p99']:.2f} "
            f"(n={req['n_latencies']})")
    for name, g in sorted(agg["gauges"].items()):
        lines.append(f"  gauge {name}: mean={g['mean']:.2f} "
                     f"min={g['min']:.0f} max={g['max']:.0f} "
                     f"hist={g['hist']}")
    if agg["counters"]:
        counts = ", ".join(f"{k}={v:.0f}"
                           for k, v in sorted(agg["counters"].items()))
        lines.append(f"  counters: {counts}")
    if agg["train"]["steps"]:
        tr = agg["train"]
        lines.append(
            f"  train: {tr['steps']} steps, dispatch p50 "
            f"{tr['dispatch_ms']['p50']:.2f} ms, nonfinite_skips "
            f"{tr['nonfinite_skips']:.0f}")
    for site, j in sorted(agg["jit"].items()):
        churn = (" <- plan-hash churn" if j["distinct_keys"] > 1 else "")
        lines.append(f"  jit {site}: {j['calls']} calls, "
                     f"{j['distinct_keys']} distinct plan key(s), "
                     f"{j['misses']} trace miss(es){churn}")
    eff = hardware_efficiency(agg)
    for phase, e in sorted(eff.items()):
        lines.append(
            f"  roofline {phase}: measured {e['measured_us_per_token']:.1f}"
            f" us/token vs modeled floor {e['roofline_us_per_token']:.3f} "
            f"us/token -> {e['efficiency']:.2%} of hardware")
    problems = reconcile(events)
    if problems:
        lines.append(f"  RECONCILE: {len(problems)} problem(s)")
        lines += [f"    {p}" for p in problems]
    elif req["phases"]:
        lines.append("  reconcile: every request reached exactly one "
                     "terminal state")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# BENCH_serving.json schema
# ---------------------------------------------------------------------------

BENCH_SCHEMA_VERSION = 1

_BENCH_ROW_FIELDS = ("preset", "plan", "requests", "tokens", "wall_s",
                     "tokens_per_s", "latency_ms", "occupancy_mean",
                     "jit_entries")


def validate_bench(payload: dict) -> list[str]:
    """Schema problems of a BENCH_serving.json payload (empty = valid):
    every row keyed by its full serialized ExecutionPlan + the measured
    latency/throughput/occupancy columns."""
    problems: list[str] = []
    if payload.get("schema") != BENCH_SCHEMA_VERSION:
        problems.append(f"schema={payload.get('schema')!r}, expected "
                        f"{BENCH_SCHEMA_VERSION}")
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        return problems + ["rows: missing or empty"]
    for i, row in enumerate(rows):
        for f in _BENCH_ROW_FIELDS:
            if f not in row:
                problems.append(f"rows[{i}]: missing {f!r}")
        plan = row.get("plan")
        if not (isinstance(plan, dict)
                and {"kernels", "parallel", "memory", "duality"} <= set(plan)):
            problems.append(f"rows[{i}]: plan is not a serialized "
                            "ExecutionPlan")
        lat = row.get("latency_ms", {})
        if not (isinstance(lat, dict) and {"p50", "p95", "p99"} <= set(lat)):
            problems.append(f"rows[{i}]: latency_ms lacks p50/p95/p99")
    return problems
