"""MoE routing/dispatch properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.models.moe import init_moe, moe_ffn, _capacity

MOE = MoEConfig(n_experts=4, top_k=2, n_shared=1, d_ff_expert=32,
                capacity_factor=2.0)
D = 64


@pytest.fixture(scope="module")
def p():
    return init_moe(jax.random.PRNGKey(0), D, MOE)


def test_grouped_equals_ungrouped_at_high_capacity(p):
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, D))
    y1, _ = moe_ffn(p, x, MOE)
    y4, _ = moe_ffn(p, x, dataclasses.replace(MOE, n_groups=4))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-5)


def test_capacity_saturation_matches_full(p):
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, D))
    y1, _ = moe_ffn(p, x, MOE)
    yf, _ = moe_ffn(p, x, dataclasses.replace(MOE, capacity_factor=100.0))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(yf), atol=1e-5)


def test_expert_contribution_is_gated(p):
    """With capacity ~inf, output == sum over top-k experts of gate * expert
    + shared expert (checked against a dense loop reference)."""
    moe = dataclasses.replace(MOE, capacity_factor=100.0, n_shared=0)
    p0 = init_moe(jax.random.PRNGKey(3), D, moe)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, D))
    y, _ = moe_ffn(p0, x, moe)

    xf = x.reshape(-1, D)
    logits = xf @ p0["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, moe.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    want = jnp.zeros_like(xf)
    for e in range(moe.n_experts):
        gu = xf @ p0["experts"]["wi"][e]
        g, u = jnp.split(gu, 2, -1)
        h = jax.nn.silu(g) * u
        ye = h @ p0["experts"]["wo"][e]
        gate = jnp.where(top_i == e, top_p, 0.0).sum(-1)
        want = want + ye * gate[:, None]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, D)),
                               np.asarray(want), atol=1e-4)


def test_aux_loss_balanced_vs_skewed(p):
    """Load-balance aux loss must be higher when all tokens hit the same
    top-k experts than when routing is spread."""
    # identical tokens => every token routes to the same top-k experts
    x_same = jnp.ones((4, 32, D))
    _, aux_skew = moe_ffn(p, x_same, MOE)
    x_spread = jax.random.normal(jax.random.PRNGKey(5), (4, 32, D))
    _, aux_spread = moe_ffn(p, x_spread, MOE)
    assert float(aux_skew) > float(aux_spread)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 4096), e=st.integers(2, 64), k=st.integers(1, 4),
       cf=st.floats(0.5, 4.0))
def test_capacity_bounds(n, e, k, cf):
    moe = MoEConfig(n_experts=e, top_k=min(k, e), capacity_factor=cf,
                    d_ff_expert=8)
    c = _capacity(n, moe)
    assert 1 <= c <= n
    assert c % 8 == 0 or c == n


def test_moe_grads_finite(p):
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, D))

    def loss(p):
        y, aux = moe_ffn(p, x, MOE)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert all(np.isfinite(np.asarray(t)).all() for t in jax.tree.leaves(g))
