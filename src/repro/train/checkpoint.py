"""Checkpointing: pytree <-> .npz with path-flattened keys + JSON metadata.

Crash-safe: writes land in a temp file (``.tmp_ckpt_*``) that is fsynced
and atomically ``os.replace``d into place — a writer killed mid-write (the
``checkpoint.save`` fault site simulates exactly this) leaves only temp
debris and never a torn file at a checkpoint name. Readers are defensive
anyway (a torn write can still slip through on exotic filesystems):
``latest_checkpoint`` validates candidates newest-first, skipping AND
garbage-collecting truncated/corrupt files instead of crashing on them, and
``restore_checkpoint`` raises a typed ``CorruptCheckpointError`` rather
than an opaque zipfile traceback. Keeps the last ``keep`` checkpoints;
restores into the example tree's structure/dtypes (so bf16 params
round-trip exactly).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import zipfile

import jax
import numpy as np

from repro.resilience.errors import CorruptCheckpointError
from repro.resilience.faults import fire

_TMP_PREFIX = ".tmp_ckpt_"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        # npz has no bf16/f8 codecs: store exotic float dtypes as f32
        # (bf16 -> f32 -> bf16 round-trips exactly); restore casts back.
        if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16",
                                                       "float8_e4m3fn",
                                                       "float8_e5m2"):
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _valid_checkpoint(path: str) -> bool:
    """True iff ``path`` is a structurally intact npz (zip) archive — a
    truncated/torn file from a crashed writer fails the central-directory
    walk or a member CRC check."""
    try:
        with zipfile.ZipFile(path) as z:
            return z.testzip() is None
    except (zipfile.BadZipFile, OSError, EOFError):
        return False


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3,
                    metadata: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=_TMP_PREFIX,
                               suffix=".npz")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    for fault in fire("checkpoint.save", step=step):
        # Simulate the writer dying mid-write: truncate the temp file the
        # way an interrupted write would and crash BEFORE the atomic
        # publish — the previous checkpoint must stay the restorable one,
        # and the debris is GC'd by the next successful save.
        with open(tmp, "r+b") as f:
            f.truncate(max(os.path.getsize(tmp) // 2, 1))
        raise fault
    os.replace(tmp, path)
    meta = {"step": step}
    meta.update(metadata or {})
    fd, mtmp = tempfile.mkstemp(dir=directory, prefix=_TMP_PREFIX,
                                suffix=".json")
    with os.fdopen(fd, "w") as f:
        json.dump(meta, f)
    os.replace(mtmp, path + ".json")
    _gc(directory, keep)
    return path


def _gc(directory: str, keep: int):
    ckpts = sorted(
        f for f in os.listdir(directory)
        if re.fullmatch(r"ckpt_\d+\.npz", f)
    )
    for old in ckpts[:-keep]:
        os.remove(os.path.join(directory, old))
        meta = os.path.join(directory, old + ".json")
        if os.path.exists(meta):
            os.remove(meta)
    # Temp debris from crashed writers (see the checkpoint.save fault site).
    for f in os.listdir(directory):
        if f.startswith(_TMP_PREFIX):
            os.remove(os.path.join(directory, f))


def latest_checkpoint(directory: str) -> str | None:
    """Newest *intact* checkpoint. Truncated/corrupt files (a writer that
    died mid-write, a torn copy) are skipped — and GC'd along with their
    metadata — instead of being returned or crashing the restore."""
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(
        f for f in os.listdir(directory)
        if re.fullmatch(r"ckpt_\d+\.npz", f)
    )
    for name in reversed(ckpts):
        path = os.path.join(directory, name)
        if _valid_checkpoint(path):
            return path
        os.remove(path)
        meta = path + ".json"
        if os.path.exists(meta):
            os.remove(meta)
    return None


def restore_checkpoint(path: str, example_tree):
    """Restore into example_tree's structure, casting to its leaf dtypes.
    Raises ``CorruptCheckpointError`` (typed) on a truncated/corrupt file —
    use ``latest_checkpoint`` to fall back to the newest intact one."""
    if not _valid_checkpoint(path):
        raise CorruptCheckpointError(
            f"checkpoint {path} is truncated or corrupt; "
            f"latest_checkpoint() skips such files")
    data = np.load(path)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
    new_leaves = []
    for kpath, leaf in leaves_p:
        key = "/".join(_path_str(p) for p in kpath)
        arr = data[key]
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
