from repro.layers import attention, mlp, norms, params, rotary  # noqa: F401
