"""Serve a reduced assigned-architecture LM with batched requests.

  PYTHONPATH=src python examples/serve_llm.py --arch qwen2-1.5b --requests 8

Demonstrates continuous batching (more requests than slots), per-request
sampling temperature, and EOS handling, on any of the 10 assigned archs.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models.decoder import init_model
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced_variant=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(params, cfg, n_slots=args.slots, max_seq=128)

    rng = np.random.default_rng(0)
    t0 = time.time()
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=(4 + rng.integers(0, 12),))
        reqs.append(engine.submit(prompt, max_new_tokens=args.max_new,
                                  temperature=args.temperature))
    finished = engine.run()
    dt = time.time() - t0
    total_toks = sum(len(r.generated) for r in finished)
    print(f"arch={args.arch} served {len(finished)} requests, "
          f"{total_toks} tokens in {dt:.2f}s "
          f"({total_toks / dt:.1f} tok/s on {args.slots} slots)")
    for r in finished[:4]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.generated}")


if __name__ == "__main__":
    main()
