"""Typed failure vocabulary shared by serving and checkpointing.

These are the *expected* production failures — every handler in the stack
catches these types (or the ``InjectedFault`` hierarchy in ``faults.py``),
never bare ``Exception`` (ci.sh greps for that outside this package):
an unrecognized error is a bug and must propagate.
"""
from __future__ import annotations


class AdmissionError(ValueError):
    """Typed backpressure: a request was rejected at (or can never pass)
    admission — over-length prompt, full pending queue, or a (plan, length)
    whose modeled HBM need exceeds the plan's budget."""


class DeadlineExceeded(TimeoutError):
    """A request exceeded its per-request deadline (measured in engine
    steps) while queued or active."""


class CorruptCheckpointError(RuntimeError):
    """A checkpoint file failed validation (truncated / torn write from a
    crashed saver). ``latest_checkpoint`` skips and GCs these; hitting this
    from ``restore_checkpoint`` means an explicit path pointed at debris."""
