"""Fused triangle-multiplication + outer-product-mean kernels: forward and
gradient parity vs the materialized ref oracles across mask/tile/dtype
combos, leg equivalence (XLA scan vs interpret-mode Pallas), the
oracle-forcing envelope, and the evoformer-level A/B."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dist import LocalDist
from repro.core.evoformer import (
    EvoformerConfig,
    init_evoformer_block,
    outer_product_mean,
    triangle_mult_incoming,
    triangle_mult_outgoing,
)
from repro.exec.plan import preset, use_plan
from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}
# The fused OPM legs keep fp32 through the c²→d projection (the reassociated
# XLA contraction / the kernel's fp32 epilogue) while the materialized oracle
# rounds the normalized outer product to the compute dtype first — in bf16
# the A/B delta is the oracle's own rounding, so the OPM bound is wider.
OPM_ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 5e-2}


def _tri_inputs(dtype, mask_mode, B=2, I=5, J=7, K=6, C=16, D=12, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 10)
    a_lin = jax.random.normal(ks[0], (B, I, K, C), dtype)
    ga = jax.random.normal(ks[1], (B, I, K, C), dtype)
    if mask_mode == "ones":
        mask = jnp.ones((B, I, K), jnp.float32)
    elif mask_mode == "sparse":
        mask = jax.random.bernoulli(ks[2], 0.6, (B, I, K)).astype(jnp.float32)
    else:  # "zeros" — fully masked rows must stay finite
        mask = jnp.zeros((B, I, K), jnp.float32)
    b_full = jax.random.normal(ks[3], (B, J, K, C), dtype)
    gamma = jax.random.normal(ks[4], (C,))
    beta = jax.random.normal(ks[5], (C,))
    w_out = jax.random.normal(ks[6], (C, D))
    b_out = jax.random.normal(ks[7], (D,))
    g_lin = jax.random.normal(ks[8], (B, I, J, D), dtype)
    g_bias = jax.random.normal(ks[9], (D,))
    return (a_lin, ga, mask, b_full, gamma, beta, w_out, b_out, g_lin, g_bias)


def _opm_inputs(dtype, mask_mode, B=2, S=5, I=6, J=8, C=8, D=12, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    a = jax.random.normal(ks[0], (B, S, I, C), dtype)
    b = jax.random.normal(ks[1], (B, S, J, C), dtype)
    if mask_mode == "ones":
        ma = jnp.ones((B, S, I), jnp.float32)
        mb = jnp.ones((B, S, J), jnp.float32)
    elif mask_mode == "sparse":
        ma = jax.random.bernoulli(ks[2], 0.7, (B, S, I)).astype(jnp.float32)
        mb = jax.random.bernoulli(ks[3], 0.7, (B, S, J)).astype(jnp.float32)
    else:  # "zeros" — norm -> 0, the +1e-3 epsilon keeps it finite
        ma = jnp.zeros((B, S, I), jnp.float32)
        mb = jnp.zeros((B, S, J), jnp.float32)
    a = a * ma[..., None].astype(dtype)
    b = b * mb[..., None].astype(dtype)
    w = jax.random.normal(ks[4], (C * C, D))
    bias = jax.random.normal(ks[5], (D,))
    return (a, b, ma, mb, w, bias)


# ---------------------------------------------------------------------------
# forward parity: every mask mode x tile (incl. non-dividing) x dtype
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mask_mode", ["ones", "sparse", "zeros"])
@pytest.mark.parametrize("tile", [0, 3, 4, 16])
def test_triangle_fwd_parity(dtype, mask_mode, tile):
    args = _tri_inputs(dtype, mask_mode)
    got = ops.fused_triangle_mult(*args, tile=tile)
    want = ref.triangle_mult_ref(*args)
    assert not bool(jnp.isnan(got).any())
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mask_mode", ["ones", "sparse", "zeros"])
@pytest.mark.parametrize("tile", [0, 3, 4, 16])
def test_opm_fwd_parity(dtype, mask_mode, tile):
    args = _opm_inputs(dtype, mask_mode)
    got = ops.fused_outer_product_mean(*args, tile=tile)
    want = ref.outer_product_mean_ref(*args)
    assert not bool(jnp.isnan(got).any())
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=OPM_ATOL[dtype], rtol=1e-2)


def test_triangle_tile_invariance():
    """The tile is a pure execution knob — results must not depend on it."""
    args = _tri_inputs(jnp.float32, "sparse", seed=3)
    outs = [ops.fused_triangle_mult(*args, tile=t) for t in (0, 2, 5, 7)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-6)


def test_opm_tile_invariance():
    args = _opm_inputs(jnp.float32, "sparse", seed=3)
    outs = [ops.fused_outer_product_mean(*args, tile=t) for t in (0, 2, 3, 8)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# gradient parity through the recompute custom_vjp
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mask_mode", ["ones", "sparse"])
@pytest.mark.parametrize("tile", [0, 3])
def test_triangle_grad_parity(mask_mode, tile):
    """jax.grad through the recompute custom_vjp (inputs + per-tile stats
    only) == autodiff of the materialized oracle, for every input."""
    args = _tri_inputs(jnp.float32, mask_mode, seed=5)
    n = len(args)

    def f1(*a):
        return jnp.sum(jnp.sin(ops.fused_triangle_mult(*a, tile=tile)))

    def f2(*a):
        return jnp.sum(jnp.sin(ref.triangle_mult_ref(*a)))

    g1 = jax.grad(f1, argnums=tuple(range(n)))(*args)
    g2 = jax.grad(f2, argnums=tuple(range(n)))(*args)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                                   rtol=1e-3)


@pytest.mark.parametrize("mask_mode", ["ones", "sparse"])
@pytest.mark.parametrize("tile", [0, 3])
def test_opm_grad_parity(mask_mode, tile):
    args = _opm_inputs(jnp.float32, mask_mode, seed=5)
    n = len(args)

    def f1(*a):
        return jnp.sum(jnp.sin(ops.fused_outer_product_mean(*a, tile=tile)))

    def f2(*a):
        return jnp.sum(jnp.sin(ref.outer_product_mean_ref(*a)))

    g1 = jax.grad(f1, argnums=tuple(range(n)))(*args)
    g2 = jax.grad(f2, argnums=tuple(range(n)))(*args)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                                   rtol=1e-3)


def test_triangle_grad_parity_bf16():
    args = _tri_inputs(jnp.bfloat16, "sparse", seed=7)

    def loss(op):
        def f(a_lin, ga, b_full, g_lin):
            full = (a_lin, ga, args[2], b_full) + args[4:8] + (g_lin, args[9])
            return jnp.sum(op(*full).astype(jnp.float32) ** 2)
        return f

    g1 = jax.grad(loss(lambda *a: ops.fused_triangle_mult(*a, tile=3)),
                  argnums=(0, 1, 2, 3))(args[0], args[1], args[3], args[8])
    g2 = jax.grad(loss(ref.triangle_mult_ref),
                  argnums=(0, 1, 2, 3))(args[0], args[1], args[3], args[8])
    for a, b in zip(g1, g2):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        scale = max(1.0, float(np.abs(b).max()))
        assert float(np.abs(a - b).max()) <= 2e-2 * scale


# ---------------------------------------------------------------------------
# leg equivalence + envelopes
# ---------------------------------------------------------------------------


def test_triangle_xla_leg_matches_pallas_interpret(monkeypatch):
    """The XLA j-block scan (default off-TPU leg) and the Pallas kernel
    (REPRO_PALLAS_INTERPRET=1 validation leg) are the same computation."""
    args = _tri_inputs(jnp.float32, "sparse", seed=9)
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    y_xla = ops.fused_triangle_mult(*args, tile=4)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    y_pallas = ops.fused_triangle_mult(*args, tile=4)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pallas),
                               atol=2e-5)


def test_opm_xla_leg_matches_pallas_interpret(monkeypatch):
    args = _opm_inputs(jnp.float32, "sparse", seed=9)
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    y_xla = ops.fused_outer_product_mean(*args, tile=4)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    y_pallas = ops.fused_outer_product_mean(*args, tile=4)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pallas),
                               atol=2e-5)


def test_triangle_oracle_forced_env(monkeypatch):
    """REPRO_FORCE_TRIANGLE_ORACLE=1 pins both ops to the jnp oracles (the
    ci.sh oracle leg) without touching the other kernels."""
    args = _tri_inputs(jnp.float32, "sparse")
    oargs = _opm_inputs(jnp.float32, "sparse")
    monkeypatch.setenv("REPRO_FORCE_TRIANGLE_ORACLE", "1")
    assert not ops.fused_triangle_supported(16, 12, jnp.float32)
    assert not ops.fused_opm_supported(8, 12, jnp.float32)
    y1 = ops.fused_triangle_mult(*args)
    y2 = ops.fused_outer_product_mean(*oargs)
    monkeypatch.delenv("REPRO_FORCE_TRIANGLE_ORACLE")
    np.testing.assert_allclose(np.asarray(y1),
                               np.asarray(ref.triangle_mult_ref(*args)),
                               atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(y2), np.asarray(ref.outer_product_mean_ref(*oargs)),
        atol=1e-6)


def test_kernels_disabled_falls_back_to_oracle():
    args = _tri_inputs(jnp.float32, "sparse")
    y_kern = ops.fused_triangle_mult(*args)
    with use_plan(preset("oracle")):
        y_ref = ops.fused_triangle_mult(*args)
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_ref),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# evoformer-level A/B: fused pair-stack sites vs the materialized jnp path
# ---------------------------------------------------------------------------

CFG = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2,
                      head_dim=8, opm_dim=8, tri_mult_dim=16, n_blocks=2)


def _pair_inputs(seed=0):
    B, r = 2, 10
    pair = jax.random.normal(jax.random.PRNGKey(seed), (B, r, r, CFG.d_pair))
    seq_mask = jnp.ones((B, r)).at[:, -2:].set(0.0)
    pair_mask = seq_mask[:, :, None] * seq_mask[:, None, :]
    return pair, pair_mask


@pytest.mark.parametrize("site", ["outgoing", "incoming", "opm"])
def test_evoformer_pair_sites_fused_vs_materialized(site):
    """Each rewired pair-stack site: the fused path equals the materialized
    jnp path (REPRO_DISABLE_KERNELS A/B) on the same params/inputs."""
    params = init_evoformer_block(jax.random.PRNGKey(0), CFG)
    pair, pair_mask = _pair_inputs()
    dist = LocalDist()

    def run():
        if site == "outgoing":
            return triangle_mult_outgoing(params["tri_mult_out"], pair,
                                          pair_mask, dist, CFG)
        if site == "incoming":
            pair_t = pair.swapaxes(1, 2)
            return triangle_mult_incoming(params["tri_mult_in"], pair,
                                          pair_t, pair_mask.swapaxes(1, 2),
                                          dist, CFG)
        B, s, r = 2, 6, pair.shape[1]
        msa = jax.random.normal(jax.random.PRNGKey(3), (B, s, r, CFG.d_msa))
        msa_mask = jnp.ones((B, s, r)).at[:, :, -2:].set(0.0)
        return outer_product_mean(params["opm"], msa, msa_mask, dist, CFG)

    got = run()
    with use_plan(preset("oracle")):
        want = run()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=1e-4)


def test_evoformer_pair_sites_grad_parity():
    """Grad parity through the rewired triangle sites (fused custom_vjp vs
    the materialized autodiff path), including the transposed-coords output
    gate of the incoming update."""
    params = init_evoformer_block(jax.random.PRNGKey(0), CFG)
    pair, pair_mask = _pair_inputs(seed=1)
    dist = LocalDist()

    def loss(p, z):
        u1 = triangle_mult_outgoing(p["tri_mult_out"], z, pair_mask, dist,
                                    CFG)
        z = z + u1
        u2 = triangle_mult_incoming(p["tri_mult_in"], z, z.swapaxes(1, 2),
                                    pair_mask.swapaxes(1, 2), dist, CFG)
        return jnp.sum((z + u2) ** 2)

    g_fused = jax.grad(loss, argnums=(0, 1))(params, pair)
    with use_plan(preset("oracle")):
        g_ref = jax.grad(loss, argnums=(0, 1))(params, pair)
    for a, b in zip(jax.tree.leaves(g_fused), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                                   rtol=1e-3)


def test_evoformer_tile_knobs_pure_execution(monkeypatch):
    """cfg.tri_k_tile / cfg.opm_s_tile are pure execution knobs through the
    evoformer sites."""
    params = init_evoformer_block(jax.random.PRNGKey(0), CFG)
    pair, pair_mask = _pair_inputs(seed=2)
    dist = LocalDist()
    cfg_t = dataclasses.replace(CFG, tri_k_tile=3, opm_s_tile=2)
    u0 = triangle_mult_outgoing(params["tri_mult_out"], pair, pair_mask,
                                dist, CFG)
    u1 = triangle_mult_outgoing(params["tri_mult_out"], pair, pair_mask,
                                dist, cfg_t)
    np.testing.assert_allclose(np.asarray(u0), np.asarray(u1), atol=1e-6)
