"""Mixture-of-Experts FFN (DeepSeek-MoE style: shared + fine-grained routed
experts, top-k softmax routing with capacity-bounded dispatch).

Dispatch strategy (TPU/GSPMD-friendly): token-choice top-k masking followed by
per-expert top-C token selection — a static-shape, sort-based formulation that
shards cleanly with experts on the `model` mesh axis (the all_to_all the paper
uses for DAP axis swaps is the same collective XLA inserts here for expert
dispatch). FLOPs scale with capacity (= k/E * cap_factor), not with E.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.layers.mlp import init_swiglu, swiglu
from repro.layers.params import Params, trunc_normal


def init_moe(key, d_model: int, moe: MoEConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    f = moe.d_ff_expert
    p: Params = {
        "router": {"w": trunc_normal(k1, (d_model, moe.n_experts), 1.0)},
        "experts": {
            "wi": trunc_normal(k2, (moe.n_experts, d_model, 2 * f), 1.0),
            "wo": jnp.zeros((moe.n_experts, f, d_model), jnp.float32),
        },
    }
    if moe.n_shared:
        p["shared"] = init_swiglu(k3, d_model, moe.n_shared * f)
    return p


def _capacity(n_tokens: int, moe: MoEConfig) -> int:
    c = int(moe.capacity_factor * n_tokens * moe.top_k / moe.n_experts)
    return min(n_tokens, max(8, (c + 7) // 8 * 8))


def moe_ffn(p: Params, x: jax.Array, moe: MoEConfig):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    dt = x.dtype

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (N, E)
    top_p, top_i = jax.lax.top_k(probs, moe.top_k)             # (N, k)
    top_p = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)
    # gate matrix: renormalized prob if expert chosen else 0
    gate_full = jnp.zeros_like(probs).at[
        jnp.arange(n)[:, None], top_i
    ].set(top_p)                                               # (N, E)

    # Per-expert top-C token selection (capacity-bounded, order-independent),
    # performed independently inside each of G token groups so the routing
    # metadata (scores, top_k sort) never crosses shards: with G = DAP degree,
    # group-local selection is shard-local and the (E, G, C/G, d) -> (E, C, d)
    # regroup is the expert-parallel all_to_all.
    g_groups = moe.n_groups if n % moe.n_groups == 0 else 1
    ng = n // g_groups
    cap_g = _capacity(ng, moe)
    cap = g_groups * cap_g
    scores = jnp.where(gate_full > 0, probs, -1.0)             # (N, E)
    scores_g = scores.reshape(g_groups, ng, moe.n_experts).transpose(0, 2, 1)
    _, tok_g = jax.lax.top_k(scores_g, cap_g)                  # (G, E, Cg)
    gate_g = gate_full.reshape(g_groups, ng, moe.n_experts).transpose(0, 2, 1)
    ge = jnp.take_along_axis(gate_g, tok_g, axis=2)            # (G, E, Cg)
    xg = xf.reshape(g_groups, ng, d)
    xe = jax.vmap(lambda xv, iv: jnp.take(xv, iv.reshape(-1), axis=0))(
        xg, tok_g
    ).reshape(g_groups, moe.n_experts, cap_g, d)               # (G, E, Cg, d)
    # regroup to expert-major (E, C, d): the EP all_to_all boundary.
    xe = xe.transpose(1, 0, 2, 3).reshape(moe.n_experts, cap, d)
    ge_e = ge.transpose(1, 0, 2).reshape(moe.n_experts, cap)

    # Expert GEMMs (batched over E; shardable on the expert axis).
    gu = jnp.einsum("ecd,edf->ecf", xe.astype(dt),
                    p["experts"]["wi"].astype(dt))
    g, u = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["experts"]["wo"].astype(dt))
    ye = ye * ge_e[..., None].astype(dt)

    # return path: back to group-major, scatter-add into each group's tokens.
    ye_g = ye.reshape(moe.n_experts, g_groups, cap_g, d).transpose(1, 0, 2, 3)
    y = jax.vmap(
        lambda acc_tokens, idx, vals: jnp.zeros((ng, d), dt).at[
            idx.reshape(-1)
        ].add(vals.reshape(-1, d))
    )(xg, tok_g, ye_g).reshape(n, d)

    # Shared experts (always active).
    if "shared" in p:
        y = y + swiglu(p["shared"], xf.astype(dt)).reshape(n, d)

    # Load-balance auxiliary loss (Switch/DeepSeek form).
    frac = jnp.mean((gate_full > 0).astype(jnp.float32), axis=0)   # (E,)
    mean_p = jnp.mean(probs, axis=0)
    aux = moe.aux_weight * moe.n_experts * jnp.sum(frac * mean_p)
    return y.reshape(b, s, d), aux
