"""Recurrent sequence mixers: parallel/chunked forms vs stepwise recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig
from repro.models.ssm import (
    init_mamba,
    init_mamba_state,
    mamba_decode,
    mamba_forward,
)
from repro.models.xlstm import (
    init_mlstm,
    init_mlstm_state,
    init_slstm,
    init_slstm_state,
    mlstm_decode,
    mlstm_forward,
    slstm_decode,
    slstm_forward,
)

B, S, D, H = 2, 16, 32, 4


@pytest.fixture
def x():
    return jax.random.normal(jax.random.PRNGKey(0), (B, S, D)) * 0.5


def test_mlstm_chunked_equals_recurrent(x):
    p = init_mlstm(jax.random.PRNGKey(1), D, H)
    out_c, st_c = mlstm_forward(p, x, H, chunk=4)
    st = init_mlstm_state(B, 2 * D, H)
    outs = []
    for t in range(S):
        o, st = mlstm_decode(p, x[:, t:t + 1], st, H)
        outs.append(o)
    out_n = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_n),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c["C"]), np.asarray(st["C"]),
                               rtol=1e-3, atol=1e-4)


def test_mlstm_chunk_size_invariance(x):
    p = init_mlstm(jax.random.PRNGKey(1), D, H)
    o1, _ = mlstm_forward(p, x, H, chunk=4)
    o2, _ = mlstm_forward(p, x, H, chunk=8)
    o3, _ = mlstm_forward(p, x, H, chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=2e-4)


def test_slstm_scan_equals_stepwise(x):
    p = init_slstm(jax.random.PRNGKey(2), D, H)
    out_s, _ = slstm_forward(p, x)
    st = init_slstm_state(B, D)
    outs = []
    for t in range(S):
        o, st = slstm_decode(p, x[:, t:t + 1], st)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(out_s),
                               np.asarray(jnp.concatenate(outs, 1)),
                               atol=1e-5)


def test_mamba_scan_equals_stepwise(x):
    ssm = SSMConfig(state_dim=8, expand=2, conv_width=4)
    p = init_mamba(jax.random.PRNGKey(3), D, ssm)
    out_m, st_m = mamba_forward(p, x, ssm)
    st = init_mamba_state(B, 2 * D, ssm)
    outs = []
    for t in range(S):
        o, st = mamba_decode(p, x[:, t:t + 1], st, ssm)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(out_m),
                               np.asarray(jnp.concatenate(outs, 1)),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_m["h"]), np.asarray(st["h"]),
                               rtol=1e-3, atol=1e-4)


def test_mamba_causality(x):
    ssm = SSMConfig(state_dim=8, expand=2, conv_width=4)
    p = init_mamba(jax.random.PRNGKey(3), D, ssm)
    y1, _ = mamba_forward(p, x, ssm)
    x2 = x.at[:, S // 2:].add(10.0)
    y2, _ = mamba_forward(p, x2, ssm)
    np.testing.assert_allclose(np.asarray(y1[:, :S // 2]),
                               np.asarray(y2[:, :S // 2]), atol=1e-5)


def test_mlstm_state_continuation(x):
    """forward(first half) state feeds forward(second half) == full forward."""
    p = init_mlstm(jax.random.PRNGKey(1), D, H)
    full, _ = mlstm_forward(p, x, H, chunk=4)
    h1, st = mlstm_forward(p, x[:, :S // 2], H, chunk=4)
    h2, _ = mlstm_forward(p, x[:, S // 2:], H, chunk=4, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=2e-4)
