"""DeepSeek-V2 236B [arXiv:2405.04434]: MLA (kv_lora=512) + fine-grained MoE
(2 shared + 160 routed, top-6), first layer dense."""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig, reduced

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", source="arXiv:2405.04434",
    n_layers=60, d_model=5120, n_heads=128, n_kv=128, d_ff=1536, vocab=102400,
    head_dim=192,  # nope(128)+rope(64) query dim; v_dim=128
    stages=(("mla+dense", 1), ("mla+moe", 59)),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536,
                  first_dense=1, d_ff_dense=12288),
    mla=MLAConfig(q_lora=1536, kv_lora=512, rope_dim=64, nope_dim=128,
                  v_dim=128),
)
REDUCED = reduced(CONFIG, stages=(("mla+dense", 1), ("mla+moe", 1)))
