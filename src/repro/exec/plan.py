"""ExecutionPlan: one first-class, frozen, hashable policy object for the
whole execution stack — kernels, parallelism, memory, and async overlap.

FastFold's value is the *composition* of its levers (DAP, fused kernels,
AutoChunk, Duality Async). Before this module each lever was toggled through
a different side channel (env vars read at import, mutable module globals,
hand-threaded kwargs); now every A/B leg, CI preset, benchmark cell, and
per-request serving scenario is a data value:

    from repro.exec import ExecutionPlan, KernelPolicy, use_plan

    plan = ExecutionPlan(kernels=KernelPolicy(triangle="oracle"))
    with use_plan(plan):
        out = alphafold_forward(params, batch, cfg)   # triangle ops -> oracle

Policy matrix (op x leg x backend) — how ``KernelPolicy`` legs resolve for
each op family in ``kernels/ops.py`` (``auto`` is the default everywhere):

    op          "auto" on TPU   "auto" off-TPU            explicit legs
    ----------  --------------  ------------------------  -------------------
    attention   Pallas kernel   XLA online-softmax scan   pallas | interpret |
                                (interpret=True: Pallas     xla | oracle
                                 interpret mode)
    triangle    Pallas kernel   XLA j-block scan          pallas | interpret |
    opm         Pallas kernel   XLA reassociated GEMMs      xla | oracle
    softmax     Pallas kernel   jnp oracle (its XLA leg)  pallas | interpret |
    layer_norm  Pallas kernel   jnp oracle                  xla | oracle
    elementwise Pallas kernel   jnp oracle                (xla == oracle for
                                                           these op families)
    attn_bwd    fused Pallas    jnp KV-scan recompute     auto | scan
                backward

  * ``enabled=False`` forces the jnp oracle for every op whose leg is
    ``auto`` (the old ``REPRO_DISABLE_KERNELS=1``); the scores-materialized
    Evoformer paths ride the same switch via ``fused_*_supported``.
  * ``interpret=True`` runs interpret-mode Pallas instead of the XLA legs on
    non-TPU backends (the old ``REPRO_PALLAS_INTERPRET=1`` validation leg).
  * ``"oracle"`` on a per-op leg pins just that op family to its jnp oracle
    (``triangle="oracle", opm="oracle"`` is the old
    ``REPRO_FORCE_TRIANGLE_ORACLE=1``).
  * ``attn_bwd="scan"`` pins the attention backward to the jnp KV-scan
    recompute (the old mutable ``ops.FORCE_SCAN_ATTN_BWD``). The choice is
    baked into the op's trace at *call* time, so it scopes correctly under
    ``use_plan`` even though the backward is traced later.
  * Off-TPU, an explicit ``"pallas"`` runs the kernel in interpret mode
    (there is no compiled Pallas backend to target).

``ParallelPolicy`` subsumes the hand-threaded ``dist=`` kwarg (the backend is
built once via ``make_dist()``), ``MemoryPolicy`` subsumes ``hbm_budget=``
plus per-knob AutoChunk overrides, and ``AsyncPolicy`` gates the Duality
overlap windows (``core/duality.overlap_window`` becomes a passthrough when
disabled).

Scoping: ``current_plan()`` returns the innermost ``use_plan`` scope's plan;
outside any scope it falls back to ``ExecutionPlan.from_env()`` — the single
env-var compatibility shim (``repro/exec/envcompat.py``), evaluated at
*plan-construction* time, never at import. Plans are consulted at trace
time only, so a jitted function traced under one plan must not be reused
under another: bind the plan per jit wrapper (what the ``FastFold`` facade
and the ServingEngine do), or pass the plan as a static jit argument — the
hashability contract exists exactly so two different plans produce two
distinct jit cache entries.

This module is import-light by design (no jax): launchers import it to set
process flags before jax initializes.
"""
from __future__ import annotations

import dataclasses
import json
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Any

_LEGS = ("auto", "pallas", "interpret", "xla", "oracle")
_ATTN_BWD_LEGS = ("auto", "scan")
_DIST_BACKENDS = ("local", "shard_map", "gspmd")


@dataclass(frozen=True)
class KernelPolicy:
    """Per-op kernel leg selection (see the policy matrix in the module
    docstring). ``enabled``/``interpret`` steer every ``auto`` op; a per-op
    field pins that op family regardless of the global switches."""

    enabled: bool = True          # False: "auto" ops -> jnp oracles
    interpret: bool = False       # off-TPU "auto" ops -> interpret-mode Pallas
    attention: str = "auto"
    triangle: str = "auto"
    opm: str = "auto"
    softmax: str = "auto"
    layer_norm: str = "auto"
    elementwise: str = "auto"     # bias_sigmoid_mul / bias_dropout_add
    attn_bwd: str = "auto"        # "scan": pin the jnp KV-scan recompute bwd

    def __post_init__(self):
        for op in ("attention", "triangle", "opm", "softmax", "layer_norm",
                   "elementwise"):
            leg = getattr(self, op)
            if leg not in _LEGS:
                raise ValueError(f"KernelPolicy.{op}={leg!r}: not in {_LEGS}")
        if self.attn_bwd not in _ATTN_BWD_LEGS:
            raise ValueError(
                f"KernelPolicy.attn_bwd={self.attn_bwd!r}: "
                f"not in {_ATTN_BWD_LEGS}")


@dataclass(frozen=True)
class ParallelPolicy:
    """Distribution backend + mesh axes — subsumes the ``dist=`` kwarg.

    ``backend``: 'local' (single device, identity collectives),
    'shard_map' (paper-faithful DAP with explicit collectives — valid only
    inside a shard_map over ``axis``), or 'gspmd' (production path;
    ``mesh`` must carry the jax Mesh). ``make_dist()`` builds the matching
    core/dist.py backend."""

    backend: str = "local"
    axis: str = "model"
    mesh: Any = None              # jax.sharding.Mesh (hashable) for 'gspmd'

    def __post_init__(self):
        if self.backend not in _DIST_BACKENDS:
            raise ValueError(f"ParallelPolicy.backend={self.backend!r}: "
                             f"not in {_DIST_BACKENDS}")

    def make_dist(self):
        from repro.core.dist import dist_from_policy

        return dist_from_policy(self)


@dataclass(frozen=True)
class MemoryPolicy:
    """HBM budget + AutoChunk knob overrides — subsumes ``hbm_budget=``.

    ``hbm_budget=None`` means the hardware default (launch.mesh.HBM_BYTES).
    Nonzero chunk/tile knobs override the EvoformerConfig's values (and are
    then pinned through the AutoChunk planner); ``auto_chunk`` overrides the
    config's planner opt-in when not None."""

    hbm_budget: int | None = None
    auto_chunk: bool | None = None
    inference_chunk: int = 0
    opm_chunk: int = 0
    attn_kv_tile: int = 0
    tri_k_tile: int = 0
    opm_s_tile: int = 0

    _KNOBS = ("inference_chunk", "opm_chunk", "attn_kv_tile", "tri_k_tile",
              "opm_s_tile")

    def apply(self, evo_cfg):
        """EvoformerConfig with this policy's overrides applied (returns the
        input unchanged when nothing overrides)."""
        updates = {k: getattr(self, k) for k in self._KNOBS
                   if getattr(self, k)}
        if self.auto_chunk is not None:
            updates["auto_chunk"] = self.auto_chunk
        if not updates:
            return evo_cfg
        return dataclasses.replace(evo_cfg, **updates)


@dataclass(frozen=True)
class AsyncPolicy:
    """Duality-Async enablement: when ``overlap_windows`` is False,
    ``core/duality.overlap_window`` is a plain passthrough (no optimization
    barrier), letting A/B cells measure the paper's §IV.C overlap."""

    overlap_windows: bool = True


# Rung 1 of the degradation ladder: the minimal-transient chunk/tile knobs
# (the most serialized settings the AutoChunk candidate sets ever pick).
_DEGRADED_MEMORY = dict(inference_chunk=1, opm_chunk=8, attn_kv_tile=32,
                        tri_k_tile=16, opm_s_tile=16)


@dataclass(frozen=True)
class ExecutionPlan:
    """The composed execution policy. Frozen and hashable: equal plans hash
    equal (jit caching with the plan as a static argument works), distinct
    plans are distinct cache keys."""

    kernels: KernelPolicy = field(default_factory=KernelPolicy)
    parallel: ParallelPolicy = field(default_factory=ParallelPolicy)
    memory: MemoryPolicy = field(default_factory=MemoryPolicy)
    duality: AsyncPolicy = field(default_factory=AsyncPolicy)

    # -- convenience builders ------------------------------------------------

    def replace(self, **kw) -> "ExecutionPlan":
        return dataclasses.replace(self, **kw)

    def with_kernels(self, **kw) -> "ExecutionPlan":
        return self.replace(kernels=dataclasses.replace(self.kernels, **kw))

    def with_parallel(self, **kw) -> "ExecutionPlan":
        return self.replace(parallel=dataclasses.replace(self.parallel, **kw))

    def with_memory(self, **kw) -> "ExecutionPlan":
        return self.replace(memory=dataclasses.replace(self.memory, **kw))

    def with_async(self, **kw) -> "ExecutionPlan":
        return self.replace(duality=dataclasses.replace(self.duality, **kw))

    def degrade(self) -> "ExecutionPlan | None":
        """Next rung of the graceful-degradation ladder (the serving
        engine's OOM fallback): (1) tighten every MemoryPolicy chunk/tile
        knob to its minimal-transient setting (serializes compute, keeps
        the kernel legs), then (2) drop to the jnp oracle kernel leg.
        Returns ``None`` when fully degraded. Each rung is a plain frozen
        plan — distinct hash, own jit cache entry — so fault-driven
        fallbacks compose with ``use_plan`` scoping like any other plan."""
        tight = dataclasses.replace(self.memory, **_DEGRADED_MEMORY)
        if self.memory != tight:
            return self.replace(memory=tight)
        if self.kernels.enabled:
            return self.with_kernels(enabled=False)
        return None

    @classmethod
    def from_env(cls) -> "ExecutionPlan":
        """Legacy-flag compatibility: build the plan the process env asks
        for. The ONLY env-var pathway left in the codebase — evaluated at
        plan-construction time (never at import), so flags set after import
        take effect (see repro/exec/envcompat.py)."""
        from repro.exec import envcompat

        return envcompat.plan_from_env()

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        """Plain-JSON form of the full plan (every telemetry event and
        BENCH_serving.json row records this, not a process-salted hash).
        A live ``ParallelPolicy.mesh`` is a device handle, not data — plans
        carrying one don't serialize."""
        if self.parallel.mesh is not None:
            raise ValueError(
                "ExecutionPlan.to_dict: ParallelPolicy.mesh holds a live "
                "device mesh; serialize the mesh-free plan and rebind the "
                "mesh on load")
        return {
            "kernels": dataclasses.asdict(self.kernels),
            "parallel": {"backend": self.parallel.backend,
                         "axis": self.parallel.axis},
            "memory": dataclasses.asdict(self.memory),
            "duality": dataclasses.asdict(self.duality),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ExecutionPlan":
        """Inverse of ``to_dict`` — round-trips to an equal (and equal-hash)
        plan, so a deserialized plan hits the same jit cache entries.
        Policy ``__post_init__`` validation applies (bad legs raise)."""
        return cls(
            kernels=KernelPolicy(**d.get("kernels", {})),
            parallel=ParallelPolicy(**d.get("parallel", {})),
            memory=MemoryPolicy(**d.get("memory", {})),
            duality=AsyncPolicy(**d.get("duality", {})),
        )

    def to_json(self) -> str:
        """Canonical JSON (sorted keys): equal plans serialize to equal
        strings, making the string itself a stable cross-process cache/
        interning key — what python ``hash()`` (per-process salted) is not."""
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ExecutionPlan":
        return cls.from_dict(json.loads(s))

    def describe(self) -> str:
        k = self.kernels
        per_op = ",".join(
            f"{op}={getattr(k, op)}" for op in
            ("attention", "triangle", "opm", "softmax", "layer_norm",
             "elementwise") if getattr(k, op) != "auto")
        return (f"kernels(enabled={k.enabled} interpret={k.interpret}"
                f"{' ' + per_op if per_op else ''} attn_bwd={k.attn_bwd}) "
                f"parallel({self.parallel.backend}) "
                f"memory(budget={self.memory.hbm_budget}) "
                f"async(overlap={self.duality.overlap_windows})")


# ---------------------------------------------------------------------------
# Named presets (the ci.sh legs; REPRO_PLAN=<name> selects one, see envcompat)
# ---------------------------------------------------------------------------

PRESETS: dict[str, ExecutionPlan] = {
    # Leg 1: kernels enabled — Pallas on TPU, XLA-native legs elsewhere.
    "default": ExecutionPlan(),
    # Leg 2: every op pinned to its jnp oracle (scores-materialized paths).
    "oracle": ExecutionPlan(kernels=KernelPolicy(enabled=False)),
    # Leg 3: interpret-mode Pallas validation off-TPU.
    "interpret": ExecutionPlan(kernels=KernelPolicy(interpret=True)),
    # Leg 4: only the pair-stack kernels pinned to their oracles.
    "triangle-oracle": ExecutionPlan(
        kernels=KernelPolicy(triangle="oracle", opm="oracle")),
}


def preset(name: str) -> ExecutionPlan:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown plan preset {name!r}; choose from {sorted(PRESETS)}"
        ) from None


# ---------------------------------------------------------------------------
# Context-local plan scoping
# ---------------------------------------------------------------------------

_PLAN: ContextVar[ExecutionPlan | None] = ContextVar("repro_execution_plan",
                                                     default=None)


def current_plan() -> ExecutionPlan:
    """The innermost ``use_plan`` scope's plan, else the env-compat plan.
    Consulted by kernels/ops.py, core/duality.py, alphafold_forward, the
    ServingEngine, … at trace time."""
    plan = _PLAN.get()
    if plan is not None:
        return plan
    return ExecutionPlan.from_env()


@contextmanager
def use_plan(plan: ExecutionPlan):
    """Scope ``plan`` as the current execution plan (re-entrant; nested
    scopes restore the outer plan on exit). Plans steer *tracing*: enter the
    scope around the traced call (or inside the traced function), and never
    share one jit wrapper across plans."""
    if not isinstance(plan, ExecutionPlan):
        raise TypeError(f"use_plan expects an ExecutionPlan, got {plan!r}")
    token = _PLAN.set(plan)
    try:
        yield plan
    finally:
        _PLAN.reset(token)
