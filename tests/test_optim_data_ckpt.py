"""Optimizers, schedules, data pipeline, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.data import lm_batches, protein_batches
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    lamb_init,
    lamb_update,
)
from repro.optim.schedules import cosine_schedule, linear_warmup
from repro.train.checkpoint import (
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.loop import make_train_step
from repro.train.state import make_train_state


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    target = jnp.array([1.0, 2.0])
    state = adamw_init(params)
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state = adamw_update(params, g, state, 0.05)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_first_step_is_lr_sized():
    params = {"w": jnp.array([1.0])}
    state = adamw_init(params)
    new, _ = adamw_update(params, {"w": jnp.array([0.3])}, state, 0.1)
    # bias-corrected Adam first step ≈ lr * sign(g)
    np.testing.assert_allclose(float((params["w"] - new["w"])[0]), 0.1,
                               atol=1e-3)


def test_lamb_trust_ratio_scales():
    params = {"w": jnp.ones((4, 4)) * 10}
    state = lamb_init(params)
    new, _ = lamb_update(params, {"w": jnp.ones((4, 4))}, state, 0.01,
                         weight_decay=0.0)
    assert float(jnp.max(jnp.abs(new["w"] - params["w"]))) > 0


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 3.0 * np.sqrt(10), rtol=1e-5)
    n2 = float(jnp.linalg.norm(clipped["a"]))
    np.testing.assert_allclose(n2, 1.0, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 20000))
def test_cosine_schedule_bounds(step):
    lr = float(cosine_schedule(step, 1e-3, 100, 10000))
    assert 0.0 < lr <= 1e-3 + 1e-9


def test_warmup_monotone():
    lrs = [float(linear_warmup(s, 1.0, 50)) for s in range(60)]
    assert all(b >= a for a, b in zip(lrs, lrs[1:]))
    assert lrs[-1] == 1.0


# --- data -------------------------------------------------------------------

def test_lm_batches_deterministic_and_shaped():
    a = next(lm_batches(vocab=100, batch=4, seq=16, seed=7))
    b = next(lm_batches(vocab=100, batch=4, seq=16, seed=7))
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.tokens.shape == (4, 16) and a.targets.shape == (4, 16)
    assert a.tokens.min() >= 0 and a.tokens.max() < 100
    # next-token alignment
    np.testing.assert_array_equal(a.tokens[:, 1:], a.targets[:, :-1])


def test_protein_batches_contract():
    pb = next(protein_batches(batch=2, n_seq=8, n_res=16, seed=0))
    assert pb.msa.shape == (2, 8, 16)
    assert pb.pseudo_beta.shape == (2, 16, 3)
    # row 0 of true MSA is the target sequence
    np.testing.assert_array_equal(pb.true_msa[:, 0], pb.aatype)
    # masked positions use the mask token
    assert (pb.msa[pb.bert_mask > 0] == 22).all()
    # CA-trace spacing ~3.8A
    d = np.linalg.norm(np.diff(pb.pseudo_beta, axis=1), axis=-1)
    np.testing.assert_allclose(d, 3.8, atol=1e-4)


# --- checkpoint + train loop -------------------------------------------------

def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((3,), jnp.bfloat16)},
            "lst": [jnp.zeros((2,)), jnp.ones((2,), jnp.int32)]}
    with tempfile.TemporaryDirectory() as d:
        for step in range(5):
            save_checkpoint(d, step, tree, keep=2)
        files = [f for f in os.listdir(d) if f.endswith(".npz")]
        assert len(files) == 2  # GC keeps last 2
        restored = restore_checkpoint(latest_checkpoint(d), tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_train_step_decreases_loss_and_accum_consistency():
    def loss_fn(params, batch, rng):
        pred = batch["x"] @ params["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {"loss": l}

    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
    w_true = jax.random.normal(jax.random.PRNGKey(1), (4, 2))
    batch = {"x": x, "y": x @ w_true}
    params = {"w": jnp.zeros((4, 2))}

    init_state, step1 = make_train_step(loss_fn, base_lr=0.1, warmup_steps=1,
                                        total_steps=1000)
    state = init_state(params)
    losses = []
    for i in range(20):
        state, m = step1(state, batch, None)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.5

    # grad-accum over micro-batches == single big batch (same data repeated)
    _, step_acc = make_train_step(loss_fn, base_lr=0.1, warmup_steps=1,
                                  total_steps=1000, accum_steps=2)
    s0 = init_state(params)
    s1, m1 = step1(s0, batch, None)
    big = {"x": jnp.concatenate([x, x]), "y": jnp.concatenate([batch["y"]] * 2)}
    s2, m2 = step_acc(s0, big, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(s1.params["w"]),
                               np.asarray(s2.params["w"]), atol=1e-5)
