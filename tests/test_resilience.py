"""Resilience suite: deterministic fault injection, retry/backoff, admission
control, quarantine, graceful plan degradation, crash-safe checkpointing,
and the non-finite grad guard — every failure path driven through the
seeded FaultInjector (no sleeps, no wall-clock, no flakes), including a
25-seed randomized chaos sweep."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.exec.plan import ExecutionPlan, preset
from repro.memory.autochunk import check_decoder_admission
from repro.models.decoder import init_model
from repro.resilience import (
    AdmissionError,
    CorruptCheckpointError,
    DeadlineExceeded,
    FaultInjector,
    FaultSpec,
    InjectedFault,
    NonFiniteFault,
    OomFault,
    RetryPolicy,
    StageTimeout,
    TransientDecodeFault,
    current_injector,
    fire,
    inject_faults,
    is_oom,
)
from repro.serving.engine import ServingEngine
from repro.train.checkpoint import (
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.loop import make_train_step


# ---------------------------------------------------------------------------
# FaultInjector
# ---------------------------------------------------------------------------


def test_fire_is_noop_outside_scope():
    assert current_injector() is None
    assert fire("decode", step=1, slot=0) == ()


def test_injector_scoping_nested_and_exception_safe():
    outer_spec = FaultSpec("oom", "decode")
    inner_spec = FaultSpec("transient", "decode")
    with inject_faults(outer_spec, seed=0) as outer:
        assert current_injector() is outer
        with inject_faults(inner_spec, seed=0) as inner:
            assert current_injector() is inner
            (f,) = fire("decode", step=1)
            assert isinstance(f, TransientDecodeFault)
        assert current_injector() is outer
        with pytest.raises(RuntimeError, match="boom"):
            with inject_faults(inner_spec, seed=0):
                raise RuntimeError("boom")
        assert current_injector() is outer       # restored despite the raise
    assert current_injector() is None


def test_spec_predicates_step_slot_uid_after_times():
    spec = FaultSpec("oom", "decode", step=3, slot=1, uid=7, after=1, times=2)
    with inject_faults(spec, seed=0) as inj:
        assert fire("decode", step=2, slot=1, uid=7) == ()   # wrong step
        assert fire("decode", step=3, slot=0, uid=7) == ()   # wrong slot
        assert fire("decode", step=3, slot=1, uid=8) == ()   # wrong uid
        assert fire("prefill", step=3, slot=1, uid=7) == ()  # wrong site
        assert fire("decode", step=3, slot=1, uid=7) == ()   # after=1 skips
        f1 = fire("decode", step=3, slot=1, uid=7)
        f2 = fire("decode", step=3, slot=1, uid=7)
        f3 = fire("decode", step=3, slot=1, uid=7)           # times exhausted
        assert len(f1) == len(f2) == 1 and f3 == ()
        assert isinstance(f1[0], OomFault)
        assert f1[0].slot == 1 and f1[0].uid == 7 and f1[0].step == 3
        assert inj.counts == {"OomFault": 2} and inj.total_fired == 2


def test_spec_pred_callable_and_unlimited_times():
    spec = FaultSpec("transient", "decode", times=None,
                     pred=lambda ctx: ctx.attempt < 3)
    with inject_faults(spec, seed=0) as inj:
        assert len(fire("decode", attempt=1)) == 1
        assert len(fire("decode", attempt=2)) == 1
        assert fire("decode", attempt=3) == ()
        assert inj.total_fired == 2


def test_probabilistic_firing_is_seed_deterministic():
    spec = FaultSpec("transient", "decode", times=None, p=0.5)

    def pattern(seed):
        with inject_faults(spec, seed=seed):
            return [bool(fire("decode", step=i)) for i in range(40)]

    a, b = pattern(123), pattern(123)
    assert a == b                            # identical seed -> identical run
    assert any(a) and not all(a)             # p=0.5 actually both-sided
    assert pattern(124) != a                 # and the seed matters


def test_spec_validation():
    with pytest.raises(ValueError, match="fault"):
        FaultSpec("segfault", "decode")
    with pytest.raises(ValueError, match="site"):
        FaultSpec("oom", "everywhere")
    with pytest.raises(ValueError, match="p="):
        FaultSpec("oom", "decode", p=1.5)
    with pytest.raises(TypeError):
        FaultInjector(["oom"], seed=0)


def test_default_seed_comes_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_FAULT_SEED", "123")
    spec = FaultSpec("transient", "decode", times=None, p=0.5)
    env_pattern = [bool(FaultInjector([spec]).fire("decode", step=0))
                   for _ in range(1)]
    inj = FaultInjector([spec])
    assert inj.seed == 123
    explicit = FaultInjector([spec], seed=123)
    got = [bool(inj.fire("decode", step=i)) for i in range(20)]
    want = [bool(explicit.fire("decode", step=i)) for i in range(20)]
    assert got == want and env_pattern is not None


def test_is_oom_covers_injected_and_runtime_strings():
    assert is_oom(OomFault(site="decode"))
    assert is_oom(RuntimeError("RESOURCE_EXHAUSTED: Out of memory on chip"))
    assert is_oom(RuntimeError("Allocator ran out of memory"))
    assert not is_oom(RuntimeError("shape mismatch"))
    assert not is_oom(TransientDecodeFault(site="decode"))


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------


def test_backoff_is_capped_exponential():
    pol = RetryPolicy(max_attempts=6, backoff=1.0, multiplier=2.0,
                      max_backoff=8.0)
    assert [pol.delay(a) for a in range(1, 6)] == [1.0, 2.0, 4.0, 8.0, 8.0]
    assert pol.delay_steps(3) == 4
    assert RetryPolicy(backoff=0.25).delay_steps(1) == 1   # never same-step


def test_jitter_is_bounded_and_deterministic():
    pol = RetryPolicy(backoff=4.0, jitter=0.5)
    d1, d2 = pol.delay(1, seed=7), pol.delay(1, seed=7)
    assert d1 == d2                                        # deterministic
    assert 2.0 <= d1 <= 6.0                                # within +/- 50%
    assert pol.delay(1, seed=8) != d1


def test_retryable_defaults_and_should_retry():
    pol = RetryPolicy(max_attempts=3)
    assert pol.should_retry(TransientDecodeFault(site="decode"), 1)
    assert pol.should_retry(StageTimeout(site="decode"), 2)
    assert not pol.should_retry(TransientDecodeFault(site="decode"), 3)
    assert not pol.should_retry(OomFault(site="decode"), 1)   # ladder's job
    assert not pol.should_retry(ValueError("bug"), 1)


def test_call_retries_with_recorded_backoff_then_succeeds():
    sleeps, calls = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransientDecodeFault(site="decode")
        return "ok"

    pol = RetryPolicy(max_attempts=3, backoff=1.0, multiplier=2.0)
    assert pol.call(flaky, sleep=sleeps.append) == "ok"
    assert len(calls) == 3 and sleeps == [1.0, 2.0]


def test_call_nonretryable_and_exhaustion_reraise():
    pol = RetryPolicy(max_attempts=2, backoff=1.0)
    with pytest.raises(ValueError):
        pol.call(lambda: (_ for _ in ()).throw(ValueError("no")),
                 sleep=lambda _: None)
    attempts = []

    def always_fails():
        attempts.append(1)
        raise StageTimeout(site="decode")

    with pytest.raises(StageTimeout):
        pol.call(always_fails, sleep=lambda _: None)
    assert len(attempts) == 2


# ---------------------------------------------------------------------------
# Graceful-degradation ladder (ExecutionPlan.degrade)
# ---------------------------------------------------------------------------


def test_degradation_ladder_memory_then_oracle_then_none():
    plan = ExecutionPlan()
    rung1 = plan.degrade()
    assert rung1 is not None and rung1.kernels.enabled
    assert rung1.memory.inference_chunk == 1      # tightened chunks
    assert rung1.memory.attn_kv_tile and rung1.memory.tri_k_tile
    rung2 = rung1.degrade()
    assert rung2 is not None and not rung2.kernels.enabled
    assert rung2.degrade() is None                # ladder exhausted
    # each rung is a distinct hashable plan (own jit cache entry)
    assert len({plan, rung1, rung2}) == 3
    # an oracle plan skips straight past the kernel rung
    oracle = preset("oracle")
    assert oracle.degrade() is not None
    assert oracle.degrade().degrade() is None


# ---------------------------------------------------------------------------
# Serving engine under failure
# ---------------------------------------------------------------------------

MAXSEQ = 24
PLEN = 6


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b", reduced_variant=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 500, size=(PLEN,)) for _ in range(n)]


def run_engine(params, cfg, prompts, *, n_slots=2, max_new=3, plans=None,
               retry=None, **engine_kw):
    eng = ServingEngine(params, cfg, n_slots=n_slots, max_seq=MAXSEQ,
                        **engine_kw)
    reqs = [eng.submit(p, max_new_tokens=max_new,
                       plan=plans[i] if plans else None, retry=retry)
            for i, p in enumerate(prompts)]
    eng.run()
    return eng, reqs


def test_admission_query_api(setup):
    cfg, _ = setup
    ok = check_decoder_admission(cfg, n_slots=2, max_seq=MAXSEQ,
                                 seq_len=PLEN, budget_bytes=1 << 34)
    assert ok.fits and 0 < ok.est_bytes <= 1 << 34
    tiny = check_decoder_admission(cfg, n_slots=2, max_seq=MAXSEQ,
                                   seq_len=PLEN, budget_bytes=1)
    assert not tiny.fits and tiny.est_bytes == ok.est_bytes
    # longer requests model more prefill bytes
    longer = check_decoder_admission(cfg, n_slots=2, max_seq=MAXSEQ,
                                     seq_len=MAXSEQ, budget_bytes=1 << 34)
    assert longer.est_bytes > ok.est_bytes
    assert "fits=False" in tiny.describe()


def test_submit_rejects_overbudget_plan(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=MAXSEQ)
    starved = eng.plan.with_memory(hbm_budget=1)
    with pytest.raises(AdmissionError, match="HBM"):
        eng.submit(np.zeros((PLEN,), np.int32), plan=starved)
    assert eng.pending == []                     # rejected, not queued


def test_bounded_pending_queue_backpressure(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, n_slots=1, max_seq=MAXSEQ,
                        max_pending=2)
    eng.submit(np.zeros((PLEN,), np.int32))
    eng.submit(np.zeros((PLEN,), np.int32))
    with pytest.raises(AdmissionError, match="backpressure"):
        eng.submit(np.zeros((PLEN,), np.int32))
    assert len(eng.pending) == 2


def test_run_fails_never_admissible_instead_of_livelock(setup):
    """Regression: a pending request that can never be admitted (over-budget
    plan, submit-time admission deferred) used to spin run() forever."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=MAXSEQ,
                        admission_control=False)
    starved = eng.plan.with_memory(hbm_budget=1)
    bad = eng.submit(np.zeros((PLEN,), np.int32), plan=starved)
    good = eng.submit(np.zeros((PLEN,), np.int32), max_new_tokens=2)
    finished = eng.run()                          # must terminate
    assert {r.uid for r in finished} == {bad.uid, good.uid}
    assert good.status == "done" and good.done
    assert bad.status == "failed" and isinstance(bad.error, AdmissionError)
    assert "never be admitted" in str(bad.error)


def test_deadline_expires_active_request(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, n_slots=1, max_seq=MAXSEQ)
    req = eng.submit(make_prompts(1)[0], max_new_tokens=50, deadline=2)
    eng.run()
    assert req.status == "failed" and not req.done
    assert isinstance(req.error, DeadlineExceeded)
    assert isinstance(req.error, TimeoutError)    # typed, catchable broadly
    assert 0 < len(req.generated) < 50            # partial work, then cut


def test_deadline_expires_queued_request(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, n_slots=1, max_seq=MAXSEQ)
    hog = eng.submit(make_prompts(1)[0], max_new_tokens=8)
    starved = eng.submit(make_prompts(1, seed=1)[0], max_new_tokens=2,
                         deadline=2)
    eng.run()
    assert hog.status == "done" and len(hog.generated) == 8
    assert starved.status == "failed"
    assert isinstance(starved.error, DeadlineExceeded)
    assert "queued" in str(starved.error)


def test_transient_decode_fault_retries_and_matches_fault_free(setup):
    cfg, params = setup
    prompts = make_prompts(1)
    _, (want,) = run_engine(params, cfg, prompts, n_slots=1, max_new=3)
    with inject_faults(FaultSpec("transient", "decode", uid=0, times=1)):
        _, (got,) = run_engine(params, cfg, prompts, n_slots=1, max_new=3,
                               retry=RetryPolicy(max_attempts=3, backoff=1.0))
    assert got.status == "done" and got.done
    assert got.attempts == 2                     # one requeue, one success
    assert got.generated == want.generated       # nothing lost or duplicated
    assert got.fallback_chain == []              # same plan throughout


def test_transient_fault_without_policy_fails_typed(setup):
    cfg, params = setup
    with inject_faults(FaultSpec("transient", "decode", uid=0, times=1)):
        _, (req,) = run_engine(params, cfg, make_prompts(1), n_slots=1)
    assert req.status == "failed" and not req.done
    assert isinstance(req.error, TransientDecodeFault)


def test_nonfinite_guard_quarantines_only_offending_slot(setup):
    """An injected NaN poisoning one slot's KV rows fails only that request;
    the surviving slot's tokens AND its KV-cache rows are bit-identical to
    a fault-free run."""
    cfg, params = setup
    prompts = make_prompts(2)
    clean_eng, clean = run_engine(params, cfg, prompts, max_new=4)
    with inject_faults(FaultSpec("nonfinite", "decode", slot=1, step=2,
                                 times=1)) as inj:
        eng, reqs = run_engine(params, cfg, prompts, max_new=4)
    assert inj.counts == {"NonFiniteFault": 1}
    assert reqs[1].status == "failed"
    assert isinstance(reqs[1].error, NonFiniteFault)
    assert reqs[0].status == "done"
    assert reqs[0].generated == clean[0].generated
    # surviving slot 0: KV rows bit-identical to the fault-free engine
    for a, b in zip(jax.tree.leaves(clean_eng.cache),
                    jax.tree.leaves(eng.cache)):
        np.testing.assert_array_equal(np.asarray(a[:, 0], np.float32),
                                      np.asarray(b[:, 0], np.float32))


def test_nonfinite_quarantine_recovers_under_retry(setup):
    """A retry policy that marks NonFiniteFault retryable requeues the
    quarantined request; its re-prefill overwrites the poisoned rows and
    the retry reproduces the fault-free tokens exactly."""
    cfg, params = setup
    prompts = make_prompts(1)
    _, (want,) = run_engine(params, cfg, prompts, n_slots=1, max_new=4)
    pol = RetryPolicy(max_attempts=3, backoff=1.0,
                      retryable=lambda e: isinstance(e, NonFiniteFault))
    with inject_faults(FaultSpec("nonfinite", "decode", uid=0, times=1)):
        _, (got,) = run_engine(params, cfg, prompts, n_slots=1, max_new=4,
                               retry=pol)
    assert got.status == "done" and got.attempts == 2
    assert got.generated == want.generated


def test_oom_walks_degradation_ladder_and_records_chain(setup):
    """An OOM that keeps firing while kernels are enabled forces the request
    down the full ladder (tight memory -> oracle leg); the fallback chain is
    recorded and the final output matches the fault-free run (the legs are
    numerically identical on this config)."""
    cfg, params = setup
    prompts = make_prompts(1)
    # Pin the starting plan: the ladder shape depends on where the request
    # starts (under REPRO_PLAN=oracle the ambient plan is already on the
    # oracle rung), and this test walks it from the top.
    start = [preset("default")]
    _, (want,) = run_engine(params, cfg, prompts, n_slots=1, max_new=3,
                            plans=start)
    spec = FaultSpec("oom", "decode", uid=0, times=None,
                     pred=lambda ctx: ctx.plan.kernels.enabled)
    with inject_faults(spec) as inj:
        _, (got,) = run_engine(params, cfg, prompts, n_slots=1, max_new=3,
                               plans=start)
    assert inj.counts["OomFault"] == 2           # default rung + memory rung
    assert got.status == "done" and got.done
    assert len(got.fallback_chain) == 2
    assert got.fallback_chain[0].kernels.enabled          # memory rung
    assert got.fallback_chain[0].memory.inference_chunk == 1
    assert not got.fallback_chain[1].kernels.enabled      # oracle rung
    assert got.plan == got.fallback_chain[-1]
    assert got.generated == want.generated


def test_oom_at_prefill_degrades_once(setup):
    cfg, params = setup
    with inject_faults(FaultSpec("oom", "prefill", uid=0, times=1)):
        _, (req,) = run_engine(params, cfg, make_prompts(1), n_slots=1)
    assert req.status == "done"
    assert len(req.fallback_chain) == 1
    assert req.fallback_chain[0].memory.inference_chunk == 1


def test_oom_ladder_exhaustion_fails_typed(setup):
    cfg, params = setup
    with inject_faults(FaultSpec("oom", "decode", uid=0, times=None)):
        _, (req,) = run_engine(params, cfg, make_prompts(1), n_slots=1,
                               plans=[preset("default")])
    assert req.status == "failed" and isinstance(req.error, OomFault)
    assert len(req.fallback_chain) == 2          # walked the whole ladder


def test_empty_fault_scope_is_bit_identical(setup):
    """With injection enabled but no specs (the production configuration of
    the instrumented engine), outputs and caches are bit-identical to a run
    with no fault scope at all — the guards cost trace time only."""
    cfg, params = setup
    prompts = make_prompts(2)
    eng_a, reqs_a = run_engine(params, cfg, prompts, max_new=3)
    with inject_faults() as inj:
        eng_b, reqs_b = run_engine(params, cfg, prompts, max_new=3)
    assert inj.total_fired == 0
    for a, b in zip(reqs_a, reqs_b):
        assert a.generated == b.generated and b.status == "done"
    for a, b in zip(jax.tree.leaves(eng_a.cache),
                    jax.tree.leaves(eng_b.cache)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# Chaos sweep: randomized fault schedules, 25+ seeds
# ---------------------------------------------------------------------------

N_CHAOS_SEEDS = 25


def _random_specs(rng) -> list[FaultSpec]:
    specs = []
    for _ in range(int(rng.integers(1, 4))):
        fault = str(rng.choice(["oom", "nonfinite", "transient", "timeout"]))
        site = "prefill" if rng.random() < 0.25 else "decode"
        specs.append(FaultSpec(
            fault, site,
            step=int(rng.integers(1, 8)) if rng.random() < 0.7 else None,
            slot=int(rng.integers(0, 2)) if (site == "decode"
                                             and rng.random() < 0.5) else None,
            times=1))
    return specs


def test_chaos_sweep_all_requests_terminal_and_reconciled(setup):
    """N mixed-plan requests under randomized fault schedules, 25 seeds:
    every request ends done or typed-failed, completed requests reproduce
    the fault-free tokens exactly (zero lost or duplicated), fired-fault
    counters reconcile against per-request outcomes, and fault-free seeds
    leave the KV cache bit-identical to the baseline."""
    cfg, params = setup
    prompts = make_prompts(4, seed=99)
    plans = [None, preset("oracle"), None, preset("oracle")]
    pol = RetryPolicy(max_attempts=3, backoff=1.0,
                      retryable=lambda e: isinstance(e, InjectedFault))

    base_eng, base = run_engine(params, cfg, prompts, max_new=3, plans=plans,
                                retry=pol)
    want = {r.uid: list(r.generated) for r in base}
    base_cache = [np.asarray(leaf, np.float32)
                  for leaf in jax.tree.leaves(base_eng.cache)]

    fired_total = 0
    for seed in range(N_CHAOS_SEEDS):
        rng = np.random.default_rng(seed)
        with inject_faults(*_random_specs(rng), seed=seed) as inj:
            eng, reqs = run_engine(params, cfg, prompts, max_new=3,
                                   plans=plans, retry=pol)
        fired_total += inj.total_fired

        assert len(eng.finished) == len(reqs) == 4, seed
        assert {r.uid for r in eng.finished} == {0, 1, 2, 3}, seed
        assert all(r is None for r in eng.slot_req), seed
        assert not np.asarray(eng.lengths).any(), seed
        for r in reqs:
            assert r.status in ("done", "failed"), (seed, r.status)
            if r.status == "done":
                # exact token parity with the fault-free baseline — even
                # after retries and ladder fallbacks (legs are numerically
                # identical on this config): zero lost/duplicated tokens.
                assert r.generated == want[r.uid], (seed, r.uid)
            else:
                assert isinstance(r.error, (InjectedFault, AdmissionError,
                                            DeadlineExceeded)), (seed, r.uid)
        # reconciliation: every fired fault is accounted for by its target
        # request having retried, degraded, or failed.
        assert inj.total_fired == len(inj.events) == \
            sum(inj.counts.values()), seed
        by_uid = {r.uid: r for r in reqs}
        for ev in inj.events:
            req = by_uid[ev.uid]
            assert (req.attempts > 1 or req.fallback_chain
                    or req.status == "failed"), (seed, ev)
        if inj.total_fired == 0:
            for a, b in zip(base_cache, jax.tree.leaves(eng.cache)):
                np.testing.assert_array_equal(a, np.asarray(b, np.float32))
    assert fired_total > 0        # the sweep actually exercised faults


# ---------------------------------------------------------------------------
# Crash-safe checkpointing
# ---------------------------------------------------------------------------


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((4,), jnp.bfloat16)}


def test_checkpoint_save_killed_mid_write(tmp_path):
    """A writer crash mid-write (fault site truncates the temp file before
    the atomic publish) must leave the previous checkpoint restorable and
    only temp debris behind — which the next successful save GCs."""
    d = str(tmp_path)
    tree = _tree()
    good = save_checkpoint(d, 0, tree)
    with inject_faults(FaultSpec("timeout", "checkpoint.save")):
        with pytest.raises(StageTimeout):
            save_checkpoint(d, 1, tree)
    assert latest_checkpoint(d) == good           # old ckpt intact
    restored = restore_checkpoint(latest_checkpoint(d), tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    debris = [f for f in os.listdir(d) if f.startswith(".tmp_ckpt_")]
    assert debris                                 # the "crashed" partial
    save_checkpoint(d, 2, tree)                   # next save GCs it
    assert not [f for f in os.listdir(d) if f.startswith(".tmp_ckpt_")]
    assert latest_checkpoint(d).endswith("ckpt_00000002.npz")


def test_latest_checkpoint_skips_and_gcs_corrupt(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    good = save_checkpoint(d, 0, tree)
    torn = os.path.join(d, "ckpt_00000001.npz")
    data = open(good, "rb").read()
    with open(torn, "wb") as f:                   # torn copy: half an npz
        f.write(data[: len(data) // 2])
    with open(torn + ".json", "w") as f:
        f.write("{}")
    assert latest_checkpoint(d) == good           # skipped, not crashed
    assert not os.path.exists(torn)               # ...and GC'd
    assert not os.path.exists(torn + ".json")
    restore_checkpoint(latest_checkpoint(d), tree)


def test_restore_corrupt_raises_typed(tmp_path):
    bad = os.path.join(str(tmp_path), "ckpt_00000000.npz")
    with open(bad, "wb") as f:
        f.write(b"not an npz at all")
    with pytest.raises(CorruptCheckpointError, match="truncated or corrupt"):
        restore_checkpoint(bad, _tree())


# ---------------------------------------------------------------------------
# Non-finite grad guard (train/loop.py)
# ---------------------------------------------------------------------------


def _quadratic_setup(guard):
    def loss_fn(params, batch, rng):
        pred = batch["x"] @ params["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {"loss": l}

    init_state, train_step = make_train_step(
        loss_fn, base_lr=0.1, warmup_steps=1, total_steps=10,
        guard_nonfinite=guard)
    params = {"w": jnp.ones((4, 2), jnp.float32)}
    return init_state(params), train_step


def _healthy_batch():
    rng = np.random.default_rng(0)
    return {"x": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "y": jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)}


def test_grad_guard_is_bitwise_noop_when_healthy():
    batch = _healthy_batch()
    state_g, step_g = _quadratic_setup(guard=True)
    state_u, step_u = _quadratic_setup(guard=False)
    for _ in range(3):
        state_g, mg = step_g(state_g, batch)
        state_u, mu = step_u(state_u, batch)
    np.testing.assert_array_equal(np.asarray(state_g.params["w"]),
                                  np.asarray(state_u.params["w"]))
    assert float(mg["nonfinite_skips"]) == 0.0
    # stable metrics-key contract: the key is present (0.0) even with the
    # guard off — downstream aggregation never sees a ragged schema
    assert float(mu["nonfinite_skips"]) == 0.0


def test_grad_guard_skips_nonfinite_step_and_counts():
    state, step = _quadratic_setup(guard=True)
    w0 = np.asarray(state.params["w"]).copy()
    bad = _healthy_batch()
    bad["x"] = bad["x"].at[0, 0].set(jnp.nan)
    state, metrics = step(state, bad)
    assert float(metrics["nonfinite_skips"]) == 1.0
    assert not np.isfinite(float(metrics["grad_norm"]))
    np.testing.assert_array_equal(np.asarray(state.params["w"]), w0)
    assert int(state.step) == 1                   # schedule clock advances
    # and the run recovers: a healthy step after the skipped one updates
    state, metrics = step(state, _healthy_batch())
    assert float(metrics["nonfinite_skips"]) == 0.0
    assert not np.array_equal(np.asarray(state.params["w"]), w0)


def test_train_step_jits_with_guard():
    state, step = _quadratic_setup(guard=True)
    jstep = jax.jit(step)
    batch = _healthy_batch()
    state, metrics = jstep(state, batch)
    estate, emetrics = _quadratic_setup(guard=True)[1](
        _quadratic_setup(guard=True)[0], batch)
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(estate.params["w"]), rtol=1e-6)
    assert float(metrics["nonfinite_skips"]) == 0.0
