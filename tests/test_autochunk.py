"""AutoChunk planner: budget safety, no-chunk-when-it-fits, knob pinning,
and the forward-level wiring."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.alphafold import FULL, SMOKE
from repro.launch.mesh import HBM_BYTES
from repro.memory.autochunk import (
    ChunkPlan,
    apply_plan,
    attention_transient_bytes,
    evoformer_peak_bytes,
    plan_decoder_blocks,
    plan_evoformer_chunks,
    resolve_evoformer_config,
)

EVO = SMOKE.evoformer


def _total(cfg, **kw):
    return sum(evoformer_peak_bytes(cfg, **kw).values())


def test_no_chunk_when_unchunked_fits():
    plan = plan_evoformer_chunks(EVO, batch=1, n_seq=8, n_res=96,
                                 budget_bytes=HBM_BYTES)
    assert plan == ChunkPlan(0, 0, 0, plan.est_bytes, HBM_BYTES, True)
    assert plan.est_bytes <= HBM_BYTES


@pytest.mark.parametrize("frac", [0.9, 0.5, 0.25, 0.1])
def test_never_exceeds_budget_when_feasible(frac):
    """Across shrinking budgets, any plan returned with fits=True stays
    within the budget by construction."""
    base = plan_evoformer_chunks(EVO, batch=1, n_seq=16, n_res=128,
                                 budget_bytes=HBM_BYTES)
    budget = int(base.est_bytes * frac)
    plan = plan_evoformer_chunks(EVO, batch=1, n_seq=16, n_res=128,
                                 budget_bytes=budget)
    if plan.fits:
        assert plan.est_bytes <= budget
    else:
        # infeasible: the planner must have returned the minimal-memory plan,
        # and no candidate can beat the budget
        assert plan.est_bytes > budget


def test_infeasible_budget_flags_not_fits():
    plan = plan_evoformer_chunks(EVO, batch=1, n_seq=16, n_res=128,
                                 budget_bytes=1)
    assert not plan.fits and plan.est_bytes > 1


def test_tighter_budget_never_less_chunking():
    base = plan_evoformer_chunks(EVO, batch=1, n_seq=16, n_res=128,
                                 budget_bytes=HBM_BYTES)
    tight = plan_evoformer_chunks(EVO, batch=1, n_seq=16, n_res=128,
                                  budget_bytes=base.est_bytes // 2)
    assert tight.est_bytes <= base.est_bytes
    assert (tight.inference_chunk, tight.opm_chunk, tight.attn_kv_tile,
            tight.tri_k_tile, tight.opm_s_tile) != (0, 0, 0, 0, 0)


def test_dap_relieves_memory_pressure():
    """Paper Table V: the per-device plan relaxes as the DAP degree grows."""
    t1 = _total(FULL.evoformer, batch=1, n_seq=512, n_res=2048, dap=1)
    t8 = _total(FULL.evoformer, batch=1, n_seq=512, n_res=2048, dap=8)
    assert t8 < t1


def test_fused_attention_bytes_scale_with_kv_tile_not_r2():
    """Acceptance: fused-path attention transient scales with the KV tile;
    the materialized path scales with R^2."""
    kw = dict(dtype_bytes=4)
    f_1k = attention_transient_bytes(8, 4, 1024, 32, kv_tile=128, fused=True,
                                     **kw)
    f_2k = attention_transient_bytes(8, 4, 2048, 32, kv_tile=128, fused=True,
                                     **kw)
    m_1k = attention_transient_bytes(8, 4, 1024, 32, fused=False, **kw)
    m_2k = attention_transient_bytes(8, 4, 2048, 32, fused=False, **kw)
    assert f_2k / f_1k < 2.5          # ~linear in R at fixed tile
    assert m_2k / m_1k > 3.5          # ~quadratic in R
    # at Evoformer scale the fused transient is far below materialized
    assert f_1k * 4 < m_1k


def test_chunk_knobs_divide_their_extents():
    """Runtime chunking is a no-op for non-dividing chunks, so the planner
    must only hand out chunks that actually divide (regression: n_res=100 is
    not divisible by any power-of-two candidate, yet a plan once claimed
    fits=True on the strength of a no-op chunk)."""
    base = plan_evoformer_chunks(EVO, batch=1, n_seq=24, n_res=100,
                                 budget_bytes=HBM_BYTES)
    plan = plan_evoformer_chunks(EVO, batch=1, n_seq=24, n_res=100,
                                 budget_bytes=max(base.est_bytes // 2, 1))
    if plan.inference_chunk:
        assert 24 % plan.inference_chunk == 0 or \
            100 % plan.inference_chunk == 0
    if plan.opm_chunk:
        assert 100 % plan.opm_chunk == 0
    # the modeled estimate uses runtime-effective (divisibility-aware)
    # chunks, so fits=True really means the runtime stays within budget
    if plan.fits:
        assert plan.est_bytes <= max(base.est_bytes // 2, 1)


def test_hand_set_knobs_are_pinned():
    cfg = dataclasses.replace(EVO, inference_chunk=3)
    plan = plan_evoformer_chunks(cfg, batch=1, n_seq=16, n_res=64,
                                 budget_bytes=HBM_BYTES)
    assert plan.inference_chunk == 3
    out = apply_plan(cfg, ChunkPlan(8, 16, 128, 0, 0, True))
    assert out.inference_chunk == 3           # hand-set wins
    assert out.opm_chunk == 16 and out.attn_kv_tile == 128


def test_resolve_respects_auto_chunk_flag():
    cfg = dataclasses.replace(EVO, auto_chunk=False)
    assert resolve_evoformer_config(cfg, batch=1, n_seq=8, n_res=64) is cfg
    cfg2 = resolve_evoformer_config(EVO, batch=1, n_seq=8, n_res=64)
    assert (cfg2.inference_chunk, cfg2.opm_chunk) == (0, 0)  # fits -> off


def test_alphafold_forward_resolves_chunks():
    """End-to-end wiring: a tight hbm_budget through alphafold_forward makes
    the resolve branch pick a chunked plan, and the outputs stay identical to
    the free-budget run (chunking is a pure execution knob)."""
    from repro.core.alphafold import alphafold_forward, init_alphafold
    from repro.data import protein_batches

    params = init_alphafold(jax.random.PRNGKey(0), SMOKE)
    pb = next(protein_batches(batch=1, n_seq=8, n_res=24, seed=0))
    batch = {k: jnp.asarray(getattr(pb, k)) for k in
             ("msa", "msa_mask", "residue_index", "aatype", "seq_mask",
              "pseudo_beta", "bert_mask", "true_msa")}
    out_auto = alphafold_forward(params, batch, SMOKE, n_recycle=0)
    base = plan_evoformer_chunks(SMOKE.evoformer, batch=1, n_seq=8, n_res=24,
                                 budget_bytes=HBM_BYTES)
    tight = base.est_bytes // 2
    plan = plan_evoformer_chunks(SMOKE.evoformer, batch=1, n_seq=8, n_res=24,
                                 budget_bytes=tight)
    assert (plan.inference_chunk, plan.opm_chunk, plan.attn_kv_tile,
            plan.tri_k_tile, plan.opm_s_tile) != (0, 0, 0, 0, 0)
    # Same tight budget through the real forward-level resolve branch.
    out_chunk = alphafold_forward(params, batch, SMOKE, n_recycle=0,
                                  hbm_budget=tight)
    np.testing.assert_allclose(np.asarray(out_auto["coords"]),
                               np.asarray(out_chunk["coords"]), atol=2e-4)


def test_decoder_plan_keeps_config_when_it_fits():
    from repro.configs import get_config

    cfg = get_config("qwen2-1.5b", reduced_variant=True)
    cfg2, plan = plan_decoder_blocks(cfg, n_slots=2, max_seq=64)
    assert plan.fits
    assert (cfg2.attn_q_block, cfg2.attn_kv_block) == \
        (cfg.attn_q_block, cfg.attn_kv_block)


def test_decoder_plan_shrinks_kv_first_under_pressure():
    from repro.configs import get_config

    cfg = get_config("qwen2-1.5b", reduced_variant=True)
    full, _ = plan_decoder_blocks(cfg, n_slots=2, max_seq=64)
    from repro.memory.autochunk import decoder_attention_bytes
    e_full = decoder_attention_bytes(cfg, n_slots=2, max_seq=64,
                                     q_block=cfg.attn_q_block,
                                     kv_block=cfg.attn_kv_block)
    cfg3, plan = plan_decoder_blocks(cfg, n_slots=2, max_seq=64,
                                     budget_bytes=e_full - 1)
    assert cfg3.attn_kv_block < cfg.attn_kv_block
    assert plan.est_bytes <= e_full - 1 or not plan.fits
