"""Distributed-equivalence tests (paper-faithful DAP + TP baseline).

These run in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count
set *before* jax import, keeping the main test process at 1 device.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(script: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


DAP_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, evoformer_stack
from repro.core.dap import dap_evoformer_stack, shard_dap_inputs
cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2, head_dim=8,
                      opm_dim=8, tri_mult_dim=16, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B,s,r = 2,8,12
msa = jax.random.normal(jax.random.PRNGKey(1),(B,s,r,cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2),(B,r,r,cfg.d_pair))
masks = (jnp.ones((B,s,r)), jnp.ones((B,r)), jnp.ones((B,r,r)))
m_ref, p_ref = evoformer_stack(params, msa, pair, *masks, cfg=cfg, remat=False)
from repro.launch.mesh import _mesh
mesh = _mesh((1,4), ("data","model"))
fn = jax.jit(dap_evoformer_stack(mesh, cfg, remat=False))
args = shard_dap_inputs(mesh, msa, pair, *masks)
m_dap, p_dap = fn(params, *args)
np.testing.assert_allclose(np.asarray(m_dap), np.asarray(m_ref), atol=3e-5)
np.testing.assert_allclose(np.asarray(p_dap), np.asarray(p_ref), atol=3e-5)
import re
txt = fn.lower(params, *args).compile().as_text()
n_a2a = len(re.findall(r"all-to-all", txt))
n_ag = len(re.findall(r"all-gather", txt))
assert n_a2a > 0 and n_ag > 0, (n_a2a, n_ag)
print("DAP_OK", n_a2a, n_ag)
"""


TP_SCRIPT = r"""
import re, numpy as np, jax, jax.numpy as jnp
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, evoformer_stack
from repro.core.tp import tp_evoformer_stack
cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2, head_dim=8,
                      opm_dim=8, tri_mult_dim=16, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B,s,r = 2,6,10
msa = jax.random.normal(jax.random.PRNGKey(1),(B,s,r,cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2),(B,r,r,cfg.d_pair))
masks = (jnp.ones((B,s,r)), jnp.ones((B,r)), jnp.ones((B,r,r)))
m_ref, p_ref = evoformer_stack(params, msa, pair, *masks, cfg=cfg, remat=False)
from repro.launch.mesh import _mesh
mesh = _mesh((1,2), ("data","model"))
fn = jax.jit(tp_evoformer_stack(mesh, cfg, remat=False))
m_tp, p_tp = fn(params, msa, pair, *masks)
np.testing.assert_allclose(np.asarray(m_tp), np.asarray(m_ref), atol=3e-5)
np.testing.assert_allclose(np.asarray(p_tp), np.asarray(p_ref), atol=3e-5)
txt = fn.lower(params, msa, pair, *masks).compile().as_text()
# count all-reduce OPS (result definitions), not name mentions — newer XLA
# text repeats the op name on operand references.
n_ar = len(re.findall(r"= \S+ all-reduce\(", txt)) or \
    len(re.findall(r"all-reduce", txt))
# paper Table III: 6 AllReduce in the forward pass per block
assert n_ar == 6, n_ar
print("TP_OK", n_ar)
"""


LM_GSPMD_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.decoder import init_model, lm_loss
cfg = get_config("qwen2-1.5b", reduced_variant=True)
params = init_model(jax.random.PRNGKey(0), cfg)
B, S = 4, 32
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
batch = {"tokens": toks, "targets": toks, "mask": jnp.ones((B, S))}
loss_ref, _ = lm_loss(params, batch, cfg)
from repro.launch.mesh import _mesh
mesh = _mesh((2, 2), ("data", "model"))
def shard_x(x):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P("data", "model", None)))
with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
    loss_sharded, _ = jax.jit(
        lambda p, b: lm_loss(p, b, cfg, shard_x=shard_x))(params, batch)
np.testing.assert_allclose(float(loss_sharded), float(loss_ref), rtol=1e-4)
print("GSPMD_LM_OK", float(loss_sharded))
"""


MINI_DRYRUN_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.configs import get_config, INPUT_SHAPES
import repro.launch.dryrun as dr
import dataclasses
from repro.launch.mesh import _mesh
mesh = _mesh((2, 4), ("data", "model"))
cfg = get_config("qwen2-1.5b", reduced_variant=True)
shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=64, global_batch=4)
fn, args, in_sh, out_sh = dr.build_train(cfg, shape, mesh)
with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
    compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
mem = compiled.memory_analysis()
assert mem is not None
from repro.roofline import analysis
flops, bts = analysis.hlo_cost(compiled.as_text())
assert flops > 0 and bts > 0
print("MINI_DRYRUN_OK", flops > 0)
"""


SHARDED_ATTN_SCRIPT = r"""
import re, numpy as np, jax, jax.numpy as jnp
from repro.core.dap import dap_evoformer_stack, shard_dap_inputs
from repro.core.dist import GspmdDist, LocalDist
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, \
    evoformer_stack
from repro.kernels import ops
from repro.launch.mesh import _mesh

cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2,
                      head_dim=8, opm_dim=8, tri_mult_dim=16, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B, s, r = 2, 8, 16   # s and r divide every tested device count
msa = jax.random.normal(jax.random.PRNGKey(1), (B, s, r, cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2), (B, r, r, cfg.d_pair))
masks = (jnp.ones((B, s, r)), jnp.ones((B, r)), jnp.ones((B, r, r)))
n_dev = len(jax.devices())

def outputs_loss(m, z):
    return jnp.sum(m ** 2) + jnp.sum(z ** 2)

m_ref, z_ref = evoformer_stack(params, msa, pair, *masks, cfg=cfg,
                               remat=False)
g_ref = jax.grad(lambda p: outputs_loss(*evoformer_stack(
    p, msa, pair, *masks, cfg=cfg, remat=False)))(params)

def check_close(got, want, tag):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=1e-4, err_msg=tag)

def check_grads(g, tag):
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        check_close(a, b, tag)

mesh = _mesh((1, n_dev), ("data", "model"))

# ---- paper-faithful DAP (ShardMapDist): kernel runs on local shards ----
fn = dap_evoformer_stack(mesh, cfg, remat=False)
args = shard_dap_inputs(mesh, msa, pair, *masks)
m, z = jax.jit(fn)(params, *args)
check_close(m, m_ref, "dap fwd msa"); check_close(z, z_ref, "dap fwd pair")
g = jax.jit(jax.grad(lambda p: outputs_loss(*fn(p, *args))))(params)
check_grads(g, "dap grad")
print("DAP_ATTN_OK", n_dev)

# ---- production path (GspmdDist): kernel shard_mapped over the mesh ----
calls = [0]
orig = GspmdDist.sharded_attention
def counting(self, *a, **kw):
    calls[0] += 1
    return orig(self, *a, **kw)
GspmdDist.sharded_attention = counting
dist = GspmdDist(mesh=mesh, axis="model")
with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
    fwd = jax.jit(lambda p: evoformer_stack(p, msa, pair, *masks, dist=dist,
                                            cfg=cfg, remat=False))
    m, z = fwd(params)
    check_close(m, m_ref, "gspmd fwd msa")
    check_close(z, z_ref, "gspmd fwd pair")
    g = jax.jit(jax.grad(lambda p: outputs_loss(*evoformer_stack(
        p, msa, pair, *masks, dist=dist, cfg=cfg, remat=False))))(params)
    check_grads(g, "gspmd grad")
    hlo = fwd.lower(params).compile().as_text()

if ops.KERNELS_ENABLED:
    # all four attention sites took the shard-mapped fused path (the scan
    # body is traced once regardless of n_blocks)
    assert calls[0] >= 4 and calls[0] % 4 == 0, calls
    print("GSPMD_FUSED_SITES_OK", calls[0])

# No all-gather may produce a merged-(B*G, ...) tensor: the old flatten
# forced GSPMD to gather the whole representation before the kernel.
merged_leads = {B * s, B * r}
bad = []
for mt in re.finditer(r"=\s*\w+\[([0-9,]+)\][^=]*? all-gather", hlo):
    dims = [int(x) for x in mt.group(1).split(",") if x]
    if len(dims) >= 4 and dims[0] in merged_leads:
        bad.append(dims)
assert not bad, bad
print("GSPMD_ATTN_OK", n_dev)
"""


DUALITY_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.core.dap import dap_evoformer_stack, shard_dap_inputs
from repro.core.duality import overlap_report
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack
from repro.launch.mesh import _mesh
cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2,
                      head_dim=8, opm_dim=8, tri_mult_dim=16, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B, s, r = 1, 8, 16
msa = jax.random.normal(jax.random.PRNGKey(1), (B, s, r, cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2), (B, r, r, cfg.d_pair))
masks = (jnp.ones((B, s, r)), jnp.ones((B, r)), jnp.ones((B, r, r)))
mesh = _mesh((1, 4), ("data", "model"))
fn = jax.jit(dap_evoformer_stack(mesh, cfg, remat=False))
args = shard_dap_inputs(mesh, msa, pair, *masks)
txt = fn.lower(params, *args).compile().as_text()
rep = overlap_report(txt)
# The wired overlap_window (evoformer block end / bias gathers) must leave a
# non-empty Duality-Async window: on backends with async collectives, at
# least one start/done pair has compute inside it; backends that schedule
# collectives synchronously (XLA:CPU) report sync_collectives only.
assert (rep["pairs_with_compute_between"] >= 1
        or (rep["pairs"] == 0 and rep["sync_collectives"] > 0)), rep
print("DUALITY_WINDOW_OK", rep)
"""


@pytest.mark.slow
def test_dap_shard_map_equals_local_oracle():
    assert "DAP_OK" in run_sub(DAP_SCRIPT, devices=4)


@pytest.mark.slow
@pytest.mark.parametrize("devices", [2, 4, 8])
def test_sharded_fused_attention_parity(devices):
    """fwd + jax.grad parity of the shard-mapped fused-attention paths vs the
    LocalDist oracle on 2/4/8-device host meshes, for both ShardMapDist
    (paper DAP) and GspmdDist (production), plus the no-merged-all-gather
    HLO assertion."""
    out = run_sub(SHARDED_ATTN_SCRIPT, devices=devices)
    assert f"DAP_ATTN_OK {devices}" in out
    assert f"GSPMD_ATTN_OK {devices}" in out


@pytest.mark.slow
def test_duality_overlap_window_certified():
    """Regression for the wired duality.overlap_window: the lowered 2-block
    DAP stack certifies a non-empty async overlap window (or, on backends
    without async collective pairs, that the collectives are synchronous —
    not sunk-and-merged away)."""
    assert "DUALITY_WINDOW_OK" in run_sub(DUALITY_SCRIPT, devices=4)


@pytest.mark.slow
def test_tp_equals_local_oracle_and_allreduce_count():
    assert "TP_OK 6" in run_sub(TP_SCRIPT, devices=2)


@pytest.mark.slow
def test_gspmd_lm_loss_matches_single_device():
    assert "GSPMD_LM_OK" in run_sub(LM_GSPMD_SCRIPT, devices=4)


@pytest.mark.slow
def test_mini_dryrun_compiles_and_analyzes():
    assert "MINI_DRYRUN_OK" in run_sub(MINI_DRYRUN_SCRIPT, devices=8)
