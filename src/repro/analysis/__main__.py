"""`python -m repro.analysis` — the CI analysis gate (ci.sh leg 7).

Runs repro-lint over the source tree, then the compiled-program contract
matrix over the requested ExecutionPlan presets, prints a findings report,
refreshes BENCH_contracts.json, and exits nonzero on any finding or
violation.

Arg parsing and the lint pass happen before jax ever imports: the host
device count must be forced (via the one env-compat module) ahead of
backend init, and `--lint-only` should work on a box with no backend at
all.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint (AST) + compiled-program contracts (HLO/"
                    "jaxpr); nonzero exit on any finding/violation.")
    ap.add_argument("--presets", default="default,oracle",
                    help="comma-separated ExecutionPlan presets for the "
                         "contract matrix (default: default,oracle)")
    ap.add_argument("--devices", type=int, default=2,
                    help="host device count for the contract meshes "
                         "(default: 2)")
    ap.add_argument("--lint-only", action="store_true",
                    help="run only the AST pass (no jax import)")
    ap.add_argument("--contracts-only", action="store_true",
                    help="run only the contract matrix")
    ap.add_argument("--cells", default="",
                    help="comma-separated substrings filtering the contract "
                         "cells (default: all; e.g. 'evoformer_fwd,dap')")
    ap.add_argument("--bench-out", default="BENCH_contracts.json",
                    help="where to write the contract-matrix records "
                         "('' to skip)")
    ap.add_argument("--lint-root", default=None,
                    help="tree to lint (default: the installed src/repro)")
    args = ap.parse_args(argv)

    failed = False

    if not args.contracts_only:
        from repro.analysis import lint

        findings = lint.lint_tree(args.lint_root)
        print(lint.render_report(findings))
        failed |= bool(findings)

    if not args.lint_only:
        # Must precede any jax import (cells.py imports jax at module top).
        from repro.exec import envcompat

        envcompat.force_host_device_count(args.devices)

        from repro.analysis import cells

        presets = [p for p in args.presets.split(",") if p]
        selected = cells.CELLS
        if args.cells:
            pats = [c for c in args.cells.split(",") if c]
            selected = tuple(c for c in cells.CELLS
                             if any(p in c.__name__ for p in pats))
            if not selected:
                print(f"no contract cell matches {pats!r}")
                return 1
        violations, rows = cells.run_matrix(presets, cells=selected)
        for row in rows:
            status = "FAIL" if row["violations"] else "ok"
            ratio = row["ratio"] if row["ratio"] is not None else "-"
            print(f"contract {row['cell']}: {status} "
                  f"(peak ratio {ratio}, "
                  f"collectives {sum(row['collectives'].values())})")
        for v in violations:
            print(f"  VIOLATION {v.render()}")
        print(f"contracts: {len(rows)} artifact(s), "
              f"{len(violations)} violation(s)")
        if args.cells and args.bench_out == ap.get_default("bench_out"):
            # A filtered run must not clobber the checked-in full-matrix
            # baseline; pass --bench-out explicitly to force a write.
            args.bench_out = ""
        if args.bench_out:
            payload = {
                "presets": presets,
                "devices": args.devices,
                "cells": rows,
            }
            with open(args.bench_out, "w", encoding="utf-8") as fh:
                json.dump(payload, fh, indent=1, sort_keys=True)
                fh.write("\n")
            print(f"wrote {os.path.abspath(args.bench_out)}")
        failed |= bool(violations)

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
