"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. Build a reduced AlphaFold behind the FastFold facade — one object binding
   (AlphaFoldConfig, ExecutionPlan) — and run folding inference.
2. Run one DAP-style training step through the same facade.
3. Serve mixed-plan folding traffic (an oracle-leg canary request beside the
   production-leg request) from the one bound session.
4. Build an assigned LLM arch and generate tokens through the serving engine.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.alphafold import SMOKE
from repro.data import protein_batches
from repro.exec import ExecutionPlan, FastFold
from repro.models.decoder import init_model
from repro.serving.engine import ServingEngine
from repro.train.loop import make_train_step

# --- 1. AlphaFold inference -------------------------------------------------
print("== AlphaFold (reduced) folding inference ==")
ff = FastFold(SMOKE, ExecutionPlan())       # config + execution policy, once
params = ff.init(jax.random.PRNGKey(0))
pb = next(protein_batches(batch=1, n_seq=8, n_res=16, seed=0))
batch = {k: jnp.asarray(getattr(pb, k)) for k in
         ("msa", "msa_mask", "residue_index", "aatype", "seq_mask",
          "pseudo_beta", "bert_mask", "true_msa")}
out = ff.forward(params, batch)             # recycling included
print("predicted CA coords:", out["coords"].shape,
      "distogram:", out["distogram_logits"].shape)

# --- 2. one training step ----------------------------------------------------
print("== one AlphaFold training step ==")
init_state, train_step = make_train_step(ff.loss_fn, base_lr=1e-3)
state = init_state(params)
state, metrics = jax.jit(train_step)(state, batch, jax.random.PRNGKey(1))
print({k: round(float(v), 3) for k, v in metrics.items()})

# --- 3. mixed-plan folding serving -------------------------------------------
print("== mixed-plan folding requests (production + oracle canary) ==")
canary_plan = ff.plan.with_kernels(enabled=False)   # jnp-oracle leg
outs = ff.serve(params, [batch, batch], plans=[None, canary_plan])
drift = float(jnp.max(jnp.abs(outs[0]["coords"] - outs[1]["coords"])))
print(f"production vs oracle-canary coords drift: {drift:.2e}")

# --- 4. LLM serving (assigned architecture) ----------------------------------
print("== qwen2 (reduced) serving ==")
cfg = get_config("qwen2-1.5b", reduced_variant=True)
lm_params = init_model(jax.random.PRNGKey(0), cfg)
engine = ServingEngine(lm_params, cfg, n_slots=2, max_seq=64)
prompt = np.random.default_rng(0).integers(0, cfg.vocab, size=(8,))
req = engine.submit(prompt, max_new_tokens=8, temperature=0.8)
engine.run()
print("prompt:", prompt.tolist())
print("generated:", req.generated)
