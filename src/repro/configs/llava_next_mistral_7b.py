"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

VLM: the SigLIP/CLIP vision tower + projector are the allowed STUB —
input_specs() supplies post-projector patch embeddings (anyres tiling yields
up to 2880 patch tokens) of shape (B, P, d_model); this config is the
language backbone that consumes them.
"""
from repro.configs.base import ModelConfig, ModalityConfig, reduced

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=32000,
    rope_theta=1e6,
    modality=ModalityConfig(kind="vision", n_prefix_tokens=2880,
                            embed_dim=4096),
)
REDUCED = reduced(CONFIG)
