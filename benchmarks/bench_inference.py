"""Paper Figs. 12-13 + Table V — inference latency vs sequence length and the
OOM frontier.

(a) measured: reduced-AlphaFold single-model inference latency across sequence
    lengths on this host (relative scaling = Fig. 12/13's x-axis behaviour);
(b) modeled: per-device activation memory of the *full* model vs sequence
    length, single-device vs DAP-8 — reproducing Table V's OOM frontier
    (AlphaFold/OpenFold OOM at 3k; FastFold DAP-8 runs 4k).
"""
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.configs.alphafold import SMOKE
from repro.core.alphafold import alphafold_forward, init_alphafold
from repro.data import protein_batches
from repro.launch.mesh import HBM_BYTES
from repro.memory.autochunk import apply_plan, plan_evoformer_chunks


def activation_bytes(n_res, n_seq=512, heads=4, d_pair=128, dap=1):
    """Dominant inference activations (paper §III.B: cubic attention term)."""
    tri_attn = n_res ** 3 * heads * 2                 # N_r^3 * H * bf16
    pair = n_res * n_res * d_pair * 2 * 4             # few pair copies
    msa = n_seq * n_res * 256 * 2 * 4
    return (tri_attn + pair + msa) / dap


def run():
    import dataclasses
    params = init_alphafold(jax.random.PRNGKey(0), SMOKE)
    fwd = jax.jit(lambda p, b: alphafold_forward(p, b, SMOKE,
                                                 n_recycle=0)["coords"])
    # paper-baseline chunking technique (§V.C) with AutoChunk choosing the
    # chunk sizes: plan against an artificially tight budget (half the
    # unchunked estimate) so the planner is forced to chunk — no hand-set
    # constants.
    free = plan_evoformer_chunks(SMOKE.evoformer, batch=1, n_seq=8, n_res=96,
                                 budget_bytes=HBM_BYTES)
    tight = plan_evoformer_chunks(SMOKE.evoformer, batch=1, n_seq=8, n_res=96,
                                  budget_bytes=max(free.est_bytes // 2, 1))
    csv_row("autochunk_plan_free", 0, free.describe())
    csv_row("autochunk_plan_tight", 0, tight.describe())
    chunk_cfg = dataclasses.replace(
        SMOKE, evoformer=apply_plan(SMOKE.evoformer, tight))
    fwd_chunk = jax.jit(lambda p, b: alphafold_forward(
        p, b, chunk_cfg, n_recycle=0)["coords"])
    for n_res in (16, 32, 64, 96):
        pb = next(protein_batches(batch=1, n_seq=8, n_res=n_res, seed=0))
        batch = {k: jnp.asarray(getattr(pb, k)) for k in
                 ("msa", "msa_mask", "residue_index", "aatype", "seq_mask",
                  "pseudo_beta", "bert_mask", "true_msa")}
        t = time_fn(fwd, params, batch, iters=5, warmup=2)
        csv_row(f"inference_latency_nres{n_res}", t, "reduced model, 1 dev")
        tc = time_fn(fwd_chunk, params, batch, iters=5, warmup=2)
        csv_row(f"inference_latency_nres{n_res}_chunked", tc,
                f"paper §V.C chunking baseline, {tc / t:.2f}x slower")

    # OOM frontier model (full model, Table V). Paper hardware: A100-80GB;
    # on the 16 GB v5e target the same frontier needs a higher DAP degree.
    A100 = 80 << 30
    for n_res in (1024, 2048, 2560, 3072, 4096):
        b1 = activation_bytes(n_res, dap=1)
        b8 = activation_bytes(n_res, dap=8)
        b64 = activation_bytes(n_res, dap=64)
        csv_row(f"oom_model_nres{n_res}_1xA100", b1 / 2**20,
                f"MB fits={b1 < A100} (paper: AlphaFold/OpenFold OOM at 3k)")
        csv_row(f"oom_model_nres{n_res}_dap8_A100", b8 / 2**20,
                f"MB fits={b8 < A100} (paper: FastFold 8 GPU runs 4k)")
        csv_row(f"oom_model_nres{n_res}_dap64_v5e", b64 / 2**20,
                f"MB fits={b64 < HBM_BYTES} (16GB v5e needs DAP-64)")


if __name__ == "__main__":
    run()
