"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV are compressed into a small latent c_kv (kv_lora=512) + a single shared
RoPE key (rope_dim=64). Training/prefill materialize per-head K/V from the
latent; decode uses the *absorbed* form (W_uk folded into the query, W_uv
applied after attention), so the per-token cache is kv_lora+rope_dim floats —
the property that makes MLA the best DAP-gather showcase among the assigned
architectures (the gathered KV operand is ~20x smaller than GQA's).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.layers.norms import init_rms_norm, rms_norm
from repro.layers.params import Params, init_dense, dense
from repro.layers.rotary import apply_rope

NEG_INF = -1e9


def init_mla(key, d_model: int, n_heads: int, mla: MLAConfig) -> Params:
    ks = iter(jax.random.split(key, 8))
    qd = mla.nope_dim + mla.rope_dim
    return {
        "q_down": init_dense(next(ks), d_model, mla.q_lora, bias=False),
        "q_norm": init_rms_norm(mla.q_lora),
        "q_up": init_dense(next(ks), mla.q_lora, n_heads * qd, bias=False),
        "kv_down": init_dense(next(ks), d_model, mla.kv_lora + mla.rope_dim,
                              bias=False),
        "kv_norm": init_rms_norm(mla.kv_lora),
        "kv_up": init_dense(next(ks), mla.kv_lora,
                            n_heads * (mla.nope_dim + mla.v_dim), bias=False),
        "out": init_dense(next(ks), n_heads * mla.v_dim, d_model, bias=False,
                          zero_init=True),
    }


def _project_q(p, x, n_heads, mla, positions, theta):
    b, s, _ = x.shape
    q = dense(p["q_up"], rms_norm(p["q_norm"], dense(p["q_down"], x)))
    q = q.reshape(b, s, n_heads, mla.nope_dim + mla.rope_dim)
    q_nope, q_rope = jnp.split(q, [mla.nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, theta)
    return q_nope, q_rope


def _compress_kv(p, x, mla, positions, theta):
    ckv = dense(p["kv_down"], x)
    c_kv, k_rope = jnp.split(ckv, [mla.kv_lora], axis=-1)
    c_kv = rms_norm(p["kv_norm"], c_kv)                 # (B, S, kv_lora)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, theta)[:, :, 0]
    return c_kv, k_rope                                  # (B, S, rope_dim)


def mla_attention_train(p, x, n_heads, mla: MLAConfig, *, positions,
                        theta: float = 10000.0, q_block: int = 512,
                        kv_block: int = 1024, gather_kv_fn=None):
    """Materialized form for train/prefill; causal; returns (out, cache)."""
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(p, x, n_heads, mla, positions, theta)
    c_kv, k_rope = _compress_kv(p, x, mla, positions, theta)
    kv = dense(p["kv_up"], c_kv).reshape(b, s, n_heads, mla.nope_dim + mla.v_dim)
    k_nope, v = jnp.split(kv, [mla.nope_dim], axis=-1)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, n_heads, mla.rope_dim))], axis=-1
    )
    if gather_kv_fn is not None:
        k, v = gather_kv_fn(k, v)
    from repro.layers.attention import blockwise_attention
    ctx = blockwise_attention(q, k, v, causal=True, q_block=q_block or s,
                              kv_block=kv_block)
    out = dense(p["out"], ctx.reshape(b, s, -1))
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_attention_decode(p, x, cache, cache_len, n_heads, mla: MLAConfig, *,
                         theta: float = 10000.0):
    """Absorbed-form decode: attention runs in latent space; cache is
    (c_kv (B, S, kv_lora), k_rope (B, S, rope_dim))."""
    b, _, d = x.shape
    pos = cache_len[:, None]                       # (B, 1)
    q_nope, q_rope = _project_q(p, x, n_heads, mla, pos, theta)

    # write this token's compressed KV
    c_new, kr_new = _compress_kv(p, x, mla, pos, theta)
    c_kv = _scatter_cache(cache["c_kv"], c_new, cache_len)
    k_rope = _scatter_cache(cache["k_rope"], kr_new, cache_len)

    # absorb W_uk into q: q_lat (B, 1, H, kv_lora)
    w_uk = p["kv_up"]["w"].reshape(mla.kv_lora, n_heads, mla.nope_dim + mla.v_dim)
    w_k = w_uk[:, :, : mla.nope_dim]               # (kv_lora, H, nope)
    w_v = w_uk[:, :, mla.nope_dim:]                # (kv_lora, H, v)
    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_k.astype(q_nope.dtype))

    scale = 1.0 / jnp.sqrt(float(mla.nope_dim + mla.rope_dim))
    logits = (
        jnp.einsum("bqhl,bsl->bhqs", q_lat, c_kv.astype(q_lat.dtype))
        + jnp.einsum("bqhr,bsr->bhqs", q_rope, k_rope.astype(q_rope.dtype))
    ).astype(jnp.float32) * scale
    valid = jnp.arange(c_kv.shape[1])[None, :] <= cache_len[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx_lat = jnp.einsum("bhqs,bsl->bqhl", probs.astype(c_kv.dtype), c_kv)
    ctx = jnp.einsum("bqhl,lhv->bqhv", ctx_lat, w_v.astype(ctx_lat.dtype))
    out = dense(p["out"], ctx.reshape(b, 1, -1))
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def _scatter_cache(cache, new, lengths):
    """cache (B, S, ...), new (B, 1, ...): write new at per-batch position.
    vmapped dynamic_update_slice lowers to a 1-slot scatter (no full-cache
    rewrite — the decode roofline reads the cache once, writes one slot)."""
    def upd(c, n, l):
        return jax.lax.dynamic_update_slice_in_dim(c, n, l, axis=0)
    return jax.vmap(upd)(cache, new.astype(cache.dtype), lengths)


def init_mla_cache(batch: int, seq: int, mla: MLAConfig, dtype=jnp.bfloat16):
    return {
        "c_kv": jnp.zeros((batch, seq, mla.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, seq, mla.rope_dim), dtype),
    }
