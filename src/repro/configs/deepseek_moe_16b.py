"""DeepSeekMoE-16B [arXiv:2401.06066]: fine-grained experts, 2 shared + 64
routed top-6, first layer dense (d_ff 10944)."""
from repro.configs.base import MoEConfig, ModelConfig, reduced

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", source="arXiv:2401.06066",
    n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_ff=1408, vocab=102400,
    stages=(("attn+dense", 1), ("attn+moe", 27)),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
                  first_dense=1, d_ff_dense=10944),
)
REDUCED = reduced(CONFIG, stages=(("attn+dense", 1), ("attn+moe", 1)))
