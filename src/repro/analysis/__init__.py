"""repro.analysis — static analysis of the source tree AND the compiled
program, wired as one CI gate (`python -m repro.analysis`, ci.sh leg 7).

The repo's hardest-won properties are invariants of *artifacts*: the AST
(where a stray env read or a bare except lives) and the lowered HLO/jaxpr
(where a merged-dim all-gather or a rematerialized transient lives). This
package checks both, with stable IDs so a finding means the same thing in a
test, in CI output, and in a suppression comment.

Two passes, one runner:

  lint.py       repro-lint — AST pass over src/repro.
  contracts.py  declarative contracts over jit(...).lower().compile()
                artifacts (HLO text, memory_analysis(), jaxpr primitives);
                jax-free, so tests feed it canned HLO.
  cells.py      the (config, ExecutionPlan preset, mesh) matrix the
                contracts run against: Evoformer fwd/grad under GspmdDist
                (all four attention sites + triangle/OPM), the shard-mapped
                fused triangle/OPM ops, the reduced 2-block AlphaFold
                dry-run, and the DAP shard_map stack + its jaxpr.
  __main__.py   `python -m repro.analysis [--presets default,oracle]
                [--lint-only|--contracts-only]` — prints findings, refreshes
                BENCH_contracts.json, exits nonzero on any violation.

Lint rules (scope in parentheses; full rationale strings in lint.RULES):

  R001  env access outside exec/envcompat.py (everywhere else) — includes
        `from os import environ`, `os.getenv`, and aliased accessors the
        old ci.sh grep missed. Every process toggle must flow through the
        one env-compat module into an ExecutionPlan field.
  R002  bare `except Exception:` / `except:` (outside repro/resilience/) —
        failure handling must see the typed fault hierarchy; a named
        `except Exception as err:` with re-dispatch is allowed.
  R003  wall-clock/host-RNG call in traced code (core/, kernels/, layers/,
        models/, memory/, optim/, train/) — time.*, stdlib random.*,
        np.random.*, datetime.now() are baked to trace-time constants
        under jit; use jax.random keys and host-side timing.
  R004  raw jnp/np einsum in an Evoformer/pair-stack module
        (core/evoformer.py, core/alphafold.py) — the r²-scale contractions
        must route through kernels/ops.py so kernel legs, AutoChunk tiling
        and the DAP sharding hooks apply. Sanctioned materialized A/B
        fallbacks carry per-line suppressions with a rationale.
  R005  materialized softmax in an Evoformer/pair-stack module (same
        scope) — jax.nn.softmax materializes the (..., r, r) probs tensor;
        use ops.fused_attention / ops.fused_softmax.
  R006  print()/sys.stdout.write in a library module (everywhere except
        obs/, analysis/, launch/, and __main__ entrypoints) — telemetry
        from library code goes through the repro.obs event sink, not
        ad-hoc stdout.

Suppression syntax (trailing on the flagged line, or on the line above):

    o = jnp.einsum(...)  # repro-lint: disable=R004
    # repro-lint: disable=R004,R005 -- rationale here
    # repro-lint: disable-file=R003        (whole-file opt-out; prefer lines)

Contracts (evaluated per matrix cell; rationale in contracts.py):

  NoMergedAllGather(leads, min_rank)  no all-gather result with a merged
      (B*G)/(B*I) leading dim — the flatten-forced-gather regression.
      `assert_no_merged_allgather` is the same finder the distributed
      tests call, so test and gate cannot drift.
  NoInvoluntaryRemat()  no all-gather feeding a dynamic-slice in the same
      computation (the static signature of resharding-via-full-
      rematerialization; XLA's warning has no HLO marker).
  CollectiveBudget(max_per_block)  static collective-op count per traced
      block stays within budget (HLO defs or jaxpr primitives).
  PeakBytesWithin(modeled, factor)  XLA's memory_analysis() peak within a
      calibrated factor of AutoChunk's transient-bytes model, both
      directions — keeps the admission-control model honest. Ratios are
      persisted per cell to BENCH_contracts.json (the first perf-trajectory
      artifact of ROADMAP open item 3).

Adding a contract for a new kernel: write a cell builder in cells.py that
lowers the kernel the way production runs it (under `use_plan(preset(...))`
+ the mesh), give it a `PeakBytesWithin` against its autochunk model term
and a `NoMergedAllGather` with the shapes a flatten would produce, add its
name to PEAK_FACTORS/COLLECTIVE_BUDGETS, run `python -m repro.analysis` to
calibrate against the measured baseline, and check in the refreshed
BENCH_contracts.json.

This package (lint + contracts) imports no jax; only cells.py does, and the
runner defers importing it until after the host device count is forced.
"""
from repro.analysis.contracts import (  # noqa: F401
    CollectiveBudget,
    CompiledArtifact,
    NoInvoluntaryRemat,
    NoMergedAllGather,
    PeakBytesWithin,
    Violation,
    assert_no_merged_allgather,
    check_all,
    find_gather_then_slice,
    find_merged_allgathers,
)
from repro.analysis.lint import (  # noqa: F401
    Finding,
    RULES,
    lint_source,
    lint_tree,
    render_report,
)
