"""Public, shape-polymorphic entry points for the Pallas kernels.

Each op:
  * reshapes arbitrary leading dims down to the kernel's canonical layout,
  * runs the Pallas kernel on TPU (the target); on other backends it runs an
    XLA-native leg with identical semantics (the jnp oracle for the
    element-wise/softmax/LN ops, the online-softmax lax.scan for fused
    attention) — interpret-mode Pallas is a per-grid-cell loop that only runs
    when the plan asks for interpret mode (the kernel-validation CI leg),
  * carries a ``jax.custom_vjp``: fused attention pairs the forward with the
    fused Pallas backward (``flash_attention_bwd_pallas``) on the Pallas leg
    and with the jnp KV-scan recompute backward elsewhere; the remaining ops
    use analytic jnp backwards that XLA fuses,
  * falls back to the pure-jnp oracle (ref.py) when the shape is outside the
    kernel envelope or kernels are globally disabled.

Toggle: every leg choice is read from the context-local ExecutionPlan
(``repro.exec.plan.current_plan()`` / ``with use_plan(plan):``) at *trace*
time — ``KernelPolicy(enabled=False)`` (the old REPRO_DISABLE_KERNELS)
forces the oracle paths everywhere, per-op legs pin one op family, and the
attention-backward choice (the old mutable ``FORCE_SCAN_ATTN_BWD``) is baked
into each op call's trace so it scopes correctly under ``use_plan``. Legacy
env vars are honored only through ``ExecutionPlan.from_env()``
(repro/exec/envcompat.py), which is what ``current_plan()`` falls back to
outside any ``use_plan`` scope.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.exec.plan import current_plan
from repro.kernels import ref
from repro.kernels.fused_elementwise import (
    bias_dropout_add_pallas,
    bias_sigmoid_mul_pallas,
)
from repro.kernels.fused_softmax import fused_softmax_pallas
from repro.kernels.layer_norm import layer_norm_pallas

# Kernel envelope: last-dim sizes beyond this would blow the VMEM tile budget
# on the v5e target (ROW_TILE rows * C * 4 B fp32 + headroom in ~16 MB VMEM).
_MAX_SOFTMAX_C = 16384
_MAX_NORM_C = 32768


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def kernel_leg(op: str) -> str:
    """Resolved execution leg for an op family under the current plan:
    'pallas' | 'interpret' | 'xla' | 'oracle'. An explicit per-op leg on
    KernelPolicy wins; 'auto' resolves to the Pallas kernel on TPU (the
    target) and to the op's XLA-native leg elsewhere — interpret-mode Pallas
    (a per-grid-cell loop) only under ``KernelPolicy.interpret`` (the
    kernel-validation CI leg), which is both faster on CPU and safe to lower
    inside large SPMD dry-runs. ``enabled=False`` sends every 'auto' op to
    its jnp oracle."""
    pol = current_plan().kernels
    leg = getattr(pol, op)
    if leg != "auto":
        return leg
    if not pol.enabled:
        return "oracle"
    if jax.default_backend() == "tpu":
        return "pallas"
    return "interpret" if pol.interpret else "xla"


def _use_pallas(leg: str) -> bool:
    """Whether a resolved leg executes the Pallas kernel (off-TPU both
    'pallas' and 'interpret' run it in interpret mode — there is no compiled
    Pallas backend to target there). For the element-wise/softmax/LN ops the
    'xla' leg IS the jnp oracle (XLA fuses it), so this is their whole
    routing decision."""
    return leg in ("pallas", "interpret")


def _interpret_for(leg: str) -> bool:
    """Interpret flag for a kernel launch: an explicit 'interpret' leg runs
    interpret mode even ON TPU (kernel-numerics debugging); everything else
    interprets only off-TPU, where no compiled Pallas backend exists."""
    return leg == "interpret" or _interpret()


# ---------------------------------------------------------------------------
# fused softmax
# ---------------------------------------------------------------------------


def _softmax_impl(scale, has_bias, has_mask, x, bias, mask):
    n, h, r, c = x.shape
    leg = kernel_leg("softmax")
    if not _use_pallas(leg) or c > _MAX_SOFTMAX_C:
        return ref.softmax_ref(x, bias if has_bias else None,
                               mask if has_mask else None, scale)
    return fused_softmax_pallas(
        x, bias if has_bias else None, mask if has_mask else None,
        scale=scale, has_bias=has_bias, has_mask=has_mask,
        interpret=_interpret_for(leg),
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _softmax_op(scale, has_bias, has_mask, x, bias, mask):
    return _softmax_impl(scale, has_bias, has_mask, x, bias, mask)


def _softmax_fwd(scale, has_bias, has_mask, x, bias, mask):
    y = _softmax_impl(scale, has_bias, has_mask, x, bias, mask)
    return y, (y, None if bias is None else bias.shape,
               None if mask is None else mask.shape)


def _softmax_bwd(scale, has_bias, has_mask, res, g):
    y, bias_shape, mask_shape = res
    yf = y.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    dot = jnp.sum(gf * yf, axis=-1, keepdims=True)
    dlogits = yf * (gf - dot)  # grad wrt (scale*x + bias + mask)
    dx = (dlogits * scale).astype(y.dtype)
    dbias = None
    if has_bias:
        b = bias_shape[0]
        n = y.shape[0]
        dbias = dlogits.reshape((b, n // b) + dlogits.shape[1:]).sum(axis=1)
    dmask = None
    if has_mask:
        dmask = dlogits.sum(axis=(1, 2))
    return dx, dbias, dmask


_softmax_op.defvjp(_softmax_fwd, _softmax_bwd)


def fused_softmax(
    x: jax.Array,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
    scale: float = 1.0,
    *,
    allow_flatten: bool = True,
) -> jax.Array:
    """softmax(scale*x + bias + mask) over the last axis.

    x: (..., H, R, C) — leading dims are flattened into N for the kernel.
    bias: (H, R, C) or (B, H, R, C), N % B == 0 (each bias batch element is
          shared by N/B consecutive rows), or None.
    mask: additive, shape (..., C) matching x's leading dims, or None.

    5D form (group attention, Evoformer): x (B, G, H, R, C) with bias
    (B, H, R, C) shared across G and mask (B, G, C). When the Pallas leg is
    inactive — or the caller passes ``allow_flatten=False`` because the
    (B, G) dims are mesh-sharded GLOBAL dims (GspmdDist) — this form
    computes WITHOUT flattening: reshaping (B, G) together would merge two
    mesh-sharded dims and force GSPMD to all-gather the whole representation
    (§Perf alphafold iter 3).
    """
    if x.ndim == 5 and not (allow_flatten
                            and _use_pallas(kernel_leg("softmax"))
                            and x.shape[-1] <= _MAX_SOFTMAX_C):
        acc = x.astype(jnp.float32) * scale
        if bias is not None:
            acc = acc + bias.astype(jnp.float32)[:, None]
        if mask is not None:
            acc = acc + mask.astype(jnp.float32)[:, :, None, None, :]
        return jax.nn.softmax(acc, axis=-1).astype(x.dtype)
    if x.ndim == 5:
        b, g, h, r, c = x.shape
        xb = x.reshape((b * g, h, r, c))
        mb = mask.reshape((-1, c)) if mask is not None else None
        out = _softmax_op(scale, bias is not None, mask is not None, xb,
                          bias, mb)
        return out.reshape(x.shape)
    *lead, h, r, c = x.shape
    if bias is not None and bias.ndim == 3:
        bias = bias[None]
    xb = x.reshape((-1, h, r, c))
    mb = mask.reshape((-1, c)) if mask is not None else None
    out = _softmax_op(scale, bias is not None, mask is not None, xb, bias, mb)
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# fused flash attention (online softmax over KV tiles; scores never in HBM)
# ---------------------------------------------------------------------------

# Envelope: head dim beyond 256 blows the (kv_tile, d_pad) VMEM working set;
# KV lengths beyond 16k belong to the decoder-LM blockwise path instead.
_MAX_ATTN_D = 256
_MAX_ATTN_S = 16384
_DEFAULT_KV_TILE = 512   # forward KV tile / backward recompute block default


def _attn_envelope_ok(q_shape, kv_len: int | None = None, dtype=None) -> bool:
    """Shape/dtype envelope of the fused attention legs (no plan consult —
    callers with a baked leg use this directly)."""
    if dtype is not None and jnp.dtype(dtype) not in (
            jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16)):
        return False
    d = q_shape[-1]
    skv = q_shape[-3] if kv_len is None else kv_len
    return d <= _MAX_ATTN_D and skv <= _MAX_ATTN_S


def fused_attention_supported(q_shape, kv_len: int | None = None,
                              dtype=None) -> bool:
    """True when ops.fused_attention will take a fused flash leg (the Pallas
    kernel on TPU, the XLA-native online-softmax leg elsewhere) for this
    shape under the current plan — callers keeping a scores-materialized A/B
    path (the evoformer's KernelPolicy(enabled=False) leg) branch on this.
    The same envelope gates the fused Pallas *backward* (``ops._attn_bwd``):
    forward and backward always agree on which leg owns a shape, so the
    saved (q, k, v, out, lse) residuals are interchangeable. q_shape is the
    4D (N, Sq, H, D) or 5D (B, G, S, H, D) query shape."""
    if kernel_leg("attention") == "oracle":
        return False
    return _attn_envelope_ok(q_shape, kv_len=kv_len, dtype=dtype)


def _attn_tiles(sq: int, skv: int, d: int, kv_tile: int):
    from repro.kernels.flash_attention import LANE, _pad_to

    d_pad = _pad_to(d, LANE)
    # 16-row q tiles: bf16's min sublane tile (f32 needs 8; 16 covers both).
    q_tile = min(128, _pad_to(sq, 16))
    kv = kv_tile or _DEFAULT_KV_TILE
    kv = min(_pad_to(kv, LANE), _pad_to(skv, LANE))
    return q_tile, kv, d_pad


def _pad_nhsd(x, s_to: int, d_to: int):
    """Zero-pad a (N, H, S, D) kernel-layout tensor to (N, H, s_to, d_to)."""
    _, _, ss, dd = x.shape
    if ss == s_to and dd == d_to:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, s_to - ss), (0, d_to - dd)))


def _attn_stage_padded(kv_tile, q, k, v, bias, mask):
    """Shared fwd/bwd staging into the padded Pallas kernel layout — one
    source of truth so the backward kernel always sees tiles padded under
    the same rules as the forward that saved its residuals. Returns
    (qt, kt, vt, bt, mt, q_tile, kv_t, sq_pad, skv_pad) with q/k/v
    transposed to (N, H, S, D) and S/D padded to the tile grid."""
    from repro.kernels.flash_attention import _pad_to

    n, sq, h, d = q.shape
    skv = k.shape[1]
    q_tile, kv_t, d_pad = _attn_tiles(sq, skv, d, kv_tile)
    sq_pad = _pad_to(sq, q_tile)
    skv_pad = _pad_to(skv, kv_t)
    qt = _pad_nhsd(q.transpose(0, 2, 1, 3), sq_pad, d_pad)
    kt = _pad_nhsd(k.transpose(0, 2, 1, 3), skv_pad, d_pad)
    vt = _pad_nhsd(v.transpose(0, 2, 1, 3), skv_pad, d_pad)
    bt = None
    if bias is not None:
        bt = jnp.pad(bias, ((0, 0), (0, 0), (0, sq_pad - sq),
                            (0, skv_pad - skv)))
    mt = None
    if mask is not None:
        mt = jnp.pad(mask, ((0, 0), (0, skv_pad - skv)))
    return qt, kt, vt, bt, mt, q_tile, kv_t, sq_pad, skv_pad


def _attn_fwd_impl(scale, has_bias, has_mask, kv_tile, leg, q, k, v, bias,
                   mask):
    """Returns (out (N, Sq, H, D), lse (N, H, Sq)). ``leg`` is the kernel
    leg resolved (from the plan) when the op was called — baked into the
    trace so forward, residuals, and backward always agree."""
    n, sq, h, d = q.shape
    skv = k.shape[1]
    bias = bias if has_bias else None
    mask = mask if has_mask else None
    if leg == "oracle" or not _attn_envelope_ok(q.shape, kv_len=skv,
                                               dtype=q.dtype):
        return ref.attention_ref(q, k, v, bias, mask, scale)
    if not _use_pallas(leg):
        # XLA-native online-softmax leg (non-TPU backends): same math, same
        # (out, lse) residuals, lax.scan over KV tiles instead of the kernel
        # grid — interpret-mode Pallas is ~2x this path on CPU smoke shapes.
        from repro.kernels.flash_attention import flash_attention_xla

        kvb = min(kv_tile or _DEFAULT_KV_TILE, skv)
        return flash_attention_xla(q, k, v, bias, mask, scale=scale,
                                   kv_tile=kvb)
    from repro.kernels.flash_attention import flash_attention_pallas

    qt, kt, vt, bt, mt, q_tile, kv_t, sq_pad, skv_pad = _attn_stage_padded(
        kv_tile, q, k, v, bias, mask)
    out, lse = flash_attention_pallas(
        qt, kt, vt, bt, mt, scale=scale, kv_len=skv, q_tile=q_tile,
        kv_tile=kv_t, has_bias=bias is not None, has_mask=mask is not None,
        interpret=_interpret_for(leg),
    )
    return out[:, :, :sq, :d].transpose(0, 2, 1, 3), lse[:, :, :sq]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5))
def _attn_op(scale, has_bias, has_mask, kv_tile, leg, bwd, q, k, v, bias,
             mask):
    out, _ = _attn_fwd_impl(scale, has_bias, has_mask, kv_tile, leg, q, k, v,
                            bias, mask)
    return out


def _attn_fwd(scale, has_bias, has_mask, kv_tile, leg, bwd, q, k, v, bias,
              mask):
    out, lse = _attn_fwd_impl(scale, has_bias, has_mask, kv_tile, leg, q, k,
                              v, bias, mask)
    # Flash recompute residuals: only (q, k, v, out, lse) + the (already
    # HBM-resident) bias/mask inputs — never the (N, H, Sq, Skv) probs.
    return out, (q, k, v, bias, mask, out, lse)


def _attn_bwd_pallas(scale, has_bias, has_mask, kv_tile, leg, res, g):
    """Fused Pallas backward: dq/dk/dv (and the bias/mask reductions) are
    computed tile-by-tile in VMEM by flash_attention_bwd_pallas from the
    saved (q, k, v, out, lse) — the fp32 (N, H, Sq, kv_block) recompute
    transient of the jnp KV-scan backward never reaches HBM. Same envelope
    as the forward kernel; the scan below stays as the oracle leg."""
    q, k, v, bias, mask, out, lse = res
    n, sq, h, d = q.shape
    skv = k.shape[1]
    from repro.kernels.flash_attention import flash_attention_bwd_pallas

    qt, kt, vt, bt, mt, q_tile, kv_t, sq_pad, skv_pad = _attn_stage_padded(
        kv_tile, q, k, v, bias, mask)
    gf = g.astype(jnp.float32)
    delta = jnp.einsum("nqhd,nqhd->nhq", gf, out.astype(jnp.float32))
    dot = _pad_nhsd(g.astype(q.dtype).transpose(0, 2, 1, 3), sq_pad,
                    qt.shape[-1])
    lse_p = jnp.pad(lse, ((0, 0), (0, 0), (0, sq_pad - sq)))
    delta_p = jnp.pad(delta, ((0, 0), (0, 0), (0, sq_pad - sq)))
    dq, dk, dv, dbias, dmask_h = flash_attention_bwd_pallas(
        qt, kt, vt, dot, lse_p, delta_p, bt, mt, scale=scale, kv_len=skv,
        q_tile=q_tile, kv_tile=kv_t, has_bias=has_bias, has_mask=has_mask,
        interpret=_interpret_for(leg),
    )
    dq = dq[:, :, :sq, :d].transpose(0, 2, 1, 3).astype(q.dtype)
    dk = dk[:, :, :skv, :d].transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv[:, :, :skv, :d].transpose(0, 2, 1, 3).astype(v.dtype)
    db = None
    if has_bias:
        db = dbias[:, :, :sq, :skv].astype(bias.dtype)
    dm = None
    if has_mask:
        dm = dmask_h.sum(axis=1)[:, :skv].astype(mask.dtype)
    return dq, dk, dv, db, dm


def _attn_bwd(scale, has_bias, has_mask, kv_tile, leg, bwd, res, g):
    """Recompute backward. On the Pallas leg (TPU, or forced interpret) and
    in-envelope shapes: the fused flash_attention_bwd_pallas kernel. Oracle
    leg: scan over KV blocks, rebuilding the probs block from (q, k, lse) —
    peak transient is (N, H, Sq, kv_block), never the full scores tensor
    (mirrors layers/attention._flash_bwd, plus bias/mask). ``leg``/``bwd``
    were resolved from the plan when the op was *called*, so a use_plan
    scope around the op call governs this backward even though it is traced
    later (KernelPolicy.attn_bwd='scan' pins the scan for A/B)."""
    q, k, v, bias, mask, out, lse = res
    if (_use_pallas(leg) and bwd != "scan"
            and _attn_envelope_ok(q.shape, kv_len=k.shape[1],
                                  dtype=q.dtype)):
        return _attn_bwd_pallas(scale, has_bias, has_mask, kv_tile, leg,
                                res, g)
    n, sq, h, d = q.shape
    skv = k.shape[1]
    kvb = min(kv_tile or _DEFAULT_KV_TILE, skv)
    nkv = -(-skv // kvb)
    skv_pad = nkv * kvb
    from repro.kernels.flash_attention import (
        apply_block_bias_mask, stage_kv_blocks)

    xs = stage_kv_blocks(k, v, bias if has_bias else None,
                         mask if has_mask else None, kvb)

    gf = g.astype(jnp.float32)
    delta = jnp.einsum("nqhd,nqhd->nhq", gf, out.astype(jnp.float32))

    def kv_step(dq, blk):
        k_j, v_j = blk["k"], blk["v"]
        s = jnp.einsum("nqhd,nkhd->nhqk", q, k_j,
                       preferred_element_type=jnp.float32) * scale
        s = apply_block_bias_mask(s, blk, n)
        p = jnp.exp(s - lse[..., None])                    # (N, H, Sq, kvb)
        dv_j = jnp.einsum("nhqk,nqhd->nkhd", p, gf)
        dp = jnp.einsum("nqhd,nkhd->nhqk", gf, v_j.astype(jnp.float32))
        ds = p * (dp - delta[..., None])                   # d(logits)
        dq = dq + jnp.einsum("nhqk,nkhd->nqhd", ds,
                             k_j.astype(jnp.float32)) * scale
        dk_j = jnp.einsum("nhqk,nqhd->nkhd", ds,
                          q.astype(jnp.float32)) * scale
        ys = {"dk": dk_j, "dv": dv_j}
        if has_bias:
            nb = bias.shape[0]
            ys["db"] = ds.reshape((nb, n // nb) + ds.shape[1:]).sum(axis=1)
        if has_mask:
            ys["dm"] = ds.sum(axis=(1, 2))
        return dq, ys

    dq0 = jnp.zeros((n, sq, h, d), jnp.float32)
    dq, ys = jax.lax.scan(kv_step, dq0, xs)
    dk = ys["dk"].swapaxes(0, 1).reshape(n, skv_pad, h, d)[:, :skv]
    dv = ys["dv"].swapaxes(0, 1).reshape(n, skv_pad, h, v.shape[-1])[:, :skv]
    dbias = None
    if has_bias:
        dbias = (ys["db"].transpose(1, 2, 3, 0, 4)
                 .reshape(bias.shape[0], h, sq, skv_pad)[..., :skv]
                 .astype(bias.dtype))
    dmask = None
    if has_mask:
        dmask = (ys["dm"].swapaxes(0, 1).reshape(n, skv_pad)[:, :skv]
                 .astype(mask.dtype))
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            dbias, dmask)


_attn_op.defvjp(_attn_fwd, _attn_bwd)


def fused_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
    scale: float | None = None,
    kv_tile: int = 0,
) -> jax.Array:
    """Flash-style fused gated attention: softmax(scale*qk^T + bias + mask)@v
    with online softmax over KV tiles — the scores tensor never reaches HBM.

    4D form: q (N, Sq, H, D); k, v (N, Skv, H, D); bias (B, H, Sq, Skv) with
        N % B == 0 (or (H, Sq, Skv) as B=1); mask (N, Skv) additive fp32.
    5D form (Evoformer group attention): q, k, v (B, G, S, H, D) with bias
        (B, H, S, S) shared across G and mask (B, G, S) additive. The (B, G)
        dims are flattened for the kernel — callers whose (B, G) dims are
        *mesh-sharded* must hand LOCAL blocks to this function (the
        ``dist.sharded_attention`` hook in core/dist.py: shard_map under
        GSPMD), or the flatten merges two sharded dims and forces an
        all-gather of the whole representation.

    ``scale`` defaults to 1/sqrt(D). ``kv_tile`` (0 = default 512) bounds the
    forward KV tile and the backward recompute block/tile — AutoChunk
    (repro.memory.autochunk) plans it from the HBM budget.

    custom_vjp: forward saves only (q, k, v, out, lse); the backward rebuilds
    the probs from them. On the Pallas leg the fused
    ``flash_attention_bwd_pallas`` kernel computes dq/dk/dv and the
    bias/mask reductions tile-by-tile in VMEM (same envelope as the forward:
    D <= 256, Skv <= 16384, fp32/bf16); elsewhere a jnp KV-block scan with a
    (N, H, Sq, kv_block) fp32 transient is the oracle leg
    (``KernelPolicy.attn_bwd='scan'`` pins it for A/B). Mask values must be
    finite (~-1e9, not -inf). Out-of-envelope shapes and
    KernelPolicy(enabled=False) fall back to the scores-materialized oracle
    (ref.attention_ref) under the same VJP. Leg choices are resolved from
    ``current_plan()`` here, once, and baked into the trace.
    """
    leg = kernel_leg("attention")
    bwd = current_plan().kernels.attn_bwd
    d = q.shape[-1]
    assert k.shape[-1] == d and v.shape[-1] == d, (q.shape, k.shape, v.shape)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if q.ndim == 5:
        b, grp, sq, h, _ = q.shape
        skv = k.shape[2]
        qf = q.reshape(b * grp, sq, h, d)
        kf = k.reshape(b * grp, skv, h, d)
        vf = v.reshape(b * grp, skv, h, d)
        mb = mask.reshape(b * grp, skv) if mask is not None else None
        out = _attn_op(scale, bias is not None, mask is not None, kv_tile,
                       leg, bwd, qf, kf, vf, bias, mb)
        return out.reshape(q.shape)
    if bias is not None and bias.ndim == 3:
        bias = bias[None]
    return _attn_op(scale, bias is not None, mask is not None, kv_tile,
                    leg, bwd, q, k, v, bias, mask)


# ---------------------------------------------------------------------------
# fused triangle multiplicative update + outer-product-mean (pair stack)
# ---------------------------------------------------------------------------

# Envelope: the tile-epilogue GEMMs keep (i_t*j_t, C) and (i_t*j_t, C*C)
# operands in VMEM — bound C (triangle channel) and C_opm². The OPM bound is
# set by the (i_t·C, j_t·C) fp32 accumulator + (C², D) weight block fitting
# ~16 MB VMEM (c=64 → 4 MB + 2 MB at i_t=j_t=16; c=128 would need 24 MB).
_MAX_TRI_C = 1024
_MAX_OPM_C = 64
# Default j output block of the XLA legs and the backward recompute scans
# (the HBM-visible transient the AutoChunk planner models). The Pallas
# kernels' internal accumulation tile default is smaller (VMEM-budgeted):
# kernels/triangle.py DEFAULT_PALLAS_TILE.
_DEFAULT_TRI_TILE = 128
_DEFAULT_OPM_TILE = 128


def _tri_dtype_ok(dtype) -> bool:
    return jnp.dtype(dtype) in (jnp.dtype(jnp.float32),
                                jnp.dtype(jnp.bfloat16))


def fused_triangle_supported(c: int, d: int, dtype=None) -> bool:
    """True when ops.fused_triangle_mult takes a fused leg (Pallas on TPU /
    interpret, the XLA j-block scan elsewhere) for this channel size/dtype
    under the current plan. Callers keeping the materialized A/B path (the
    Evoformer's KernelPolicy(enabled=False) leg, or the per-op
    ``triangle='oracle'`` pin of the ci.sh triangle-oracle preset) branch
    on this."""
    if kernel_leg("triangle") == "oracle":
        return False
    if dtype is not None and not _tri_dtype_ok(dtype):
        return False
    return c <= _MAX_TRI_C and d <= _MAX_TRI_C


def fused_opm_supported(c: int, d: int, dtype=None) -> bool:
    """Same contract as fused_triangle_supported, for the outer-product-mean
    (c is the OPM channel — the kernel tile holds c² lanes); routed by the
    plan's ``opm`` leg."""
    if kernel_leg("opm") == "oracle":
        return False
    if dtype is not None and not _tri_dtype_ok(dtype):
        return False
    return c <= _MAX_OPM_C and d <= _MAX_TRI_C


def _tri_fwd_impl(eps, tile, leg, a_lin, ga, mask, b_full, gamma, beta,
                  w_out, b_out, g_lin, g_bias):
    from repro.kernels import triangle as tri

    if _use_pallas(leg):
        return tri.fused_triangle_pallas(
            a_lin, ga, mask, b_full, gamma, beta, w_out, b_out, g_lin,
            g_bias, eps=eps, k_tile=tile, interpret=_interpret_for(leg))
    a = tri.triangle_gate_a(a_lin, ga, mask)
    return tri.fused_triangle_xla(
        a, b_full, g_lin, gamma, beta, w_out, b_out, g_bias, eps=eps,
        j_block=tile or _DEFAULT_TRI_TILE)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _tri_op(eps, tile, leg, a_lin, ga, mask, b_full, gamma, beta, w_out,
            b_out, g_lin, g_bias):
    out, _, _ = _tri_fwd_impl(eps, tile, leg, a_lin, ga, mask, b_full, gamma,
                              beta, w_out, b_out, g_lin, g_bias)
    return out


def _tri_fwd(eps, tile, leg, a_lin, ga, mask, b_full, gamma, beta, w_out,
             b_out, g_lin, g_bias):
    out, mean, inv = _tri_fwd_impl(eps, tile, leg, a_lin, ga, mask, b_full,
                                   gamma, beta, w_out, b_out, g_lin, g_bias)
    # Recompute residuals: inputs + per-tile LN stats + the (already
    # HBM-resident) output — never the (B, I, J, C) product. `out` gives the
    # output-gate cotangent directly (g·out·(1-s), see triangle_mult_bwd).
    return out, (a_lin, ga, mask, b_full, gamma, beta, w_out, b_out, g_lin,
                 g_bias, mean, inv, out)


def _tri_bwd(eps, tile, leg, res, g):
    from repro.kernels.triangle import triangle_mult_bwd

    return triangle_mult_bwd(eps, tile or _DEFAULT_TRI_TILE, res, g)


_tri_op.defvjp(_tri_fwd, _tri_bwd)


def fused_triangle_mult(
    a_lin: jax.Array,
    ga: jax.Array,
    mask: jax.Array,
    b_full: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    w_out: jax.Array,
    b_out: jax.Array,
    g_lin: jax.Array,
    g_bias: jax.Array,
    *,
    eps: float = 1e-5,
    tile: int = 0,
) -> jax.Array:
    """Fused triangular multiplicative update:
    ``sigmoid(g_lin + g_bias) * (LN_c(sum_k (a_lin·σ(ga)·mask) ⊙ b_full) @
    w_out + b_out)`` in one sweep — the k-tiled product, input gating, pair
    mask, output LayerNorm and the bias_sigmoid_mul output gate never
    materialize intermediates at full (B, I, J, C) size.

    Shapes: a_lin/ga (B, I, K, C); mask (B, I, K); b_full (B, J, K, C)
    (gated+masked right operand — gathered under DAP; callers whose I dim is
    mesh-sharded go through ``dist.sharded_triangle`` so the kernel sees
    local blocks); gamma/beta (C,); w_out (C, D); b_out/g_bias (D,);
    g_lin (B, I, J, D). ``tile`` is the Pallas k tile / XLA j block /
    backward recompute block (0 = leg default: Pallas 64, XLA/backward
    128) — AutoChunk plans it as ``tri_k_tile``.

    custom_vjp: forward saves inputs + per-tile (mean, inv) LN stats; the
    backward rebuilds the product per j block (kernels/triangle.py).
    Out-of-envelope dtypes/channels, KernelPolicy(enabled=False), and the
    per-op ``triangle='oracle'`` leg fall back to ref.triangle_mult_ref.
    """
    if not fused_triangle_supported(a_lin.shape[-1], w_out.shape[-1],
                                    a_lin.dtype):
        return ref.triangle_mult_ref(a_lin, ga, mask, b_full, gamma, beta,
                                     w_out, b_out, g_lin, g_bias, eps)
    return _tri_op(eps, tile, kernel_leg("triangle"), a_lin, ga, mask,
                   b_full, gamma, beta, w_out, b_out, g_lin, g_bias)


def _opm_fwd_impl(tile, leg, a, b_full, mask_a, mask_b, w, bias):
    from repro.kernels import triangle as tri

    if _use_pallas(leg):
        return tri.fused_opm_pallas(a, b_full, mask_a, mask_b, w, bias,
                                    s_tile=tile,
                                    interpret=_interpret_for(leg))
    return tri.fused_opm_xla(a, b_full, mask_a, mask_b, w, bias,
                             j_block=tile or _DEFAULT_OPM_TILE)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _opm_op(tile, leg, a, b_full, mask_a, mask_b, w, bias):
    return _opm_fwd_impl(tile, leg, a, b_full, mask_a, mask_b, w, bias)


def _opm_fwd(tile, leg, a, b_full, mask_a, mask_b, w, bias):
    out = _opm_fwd_impl(tile, leg, a, b_full, mask_a, mask_b, w, bias)
    # Residuals: inputs + the (already HBM-resident) output — `out` turns
    # the mask-norm cotangent into a cheap (B, I, J, D) contraction instead
    # of a full ov·(g@wᵀ) reduction over c² (see opm_bwd).
    return out, (a, b_full, mask_a, mask_b, w, bias, out)


def _opm_bwd(tile, leg, res, g):
    from repro.kernels.triangle import opm_bwd

    return opm_bwd(tile or _DEFAULT_OPM_TILE, res, g)


_opm_op.defvjp(_opm_fwd, _opm_bwd)


def fused_outer_product_mean(
    a: jax.Array,
    b_full: jax.Array,
    mask_a: jax.Array,
    mask_b: jax.Array,
    w: jax.Array,
    bias: jax.Array,
    *,
    tile: int = 0,
) -> jax.Array:
    """Fused outer-product-mean: s-tiled accumulation of
    ``sum_s a_si ⊗ b_sj`` with the fp32 mask-normalization and the c²→d
    projection fused, so the (B, I, J, C, C) transient never reaches HBM at
    full size.

    Shapes: a (B, S, I, C), b_full (B, S, J, C) masked projections (b
    gathered under DAP — mesh-sharded I goes through ``dist.sharded_opm``);
    mask_a (B, S, I), mask_b (B, S, J); w (C*C, D), bias (D,). ``tile`` is
    the Pallas s tile / XLA j block / backward recompute block (0 = leg
    default: Pallas 64, XLA/backward 128) — AutoChunk plans it as
    ``opm_s_tile``.

    custom_vjp: forward saves only the inputs (the mask-norm is recomputed);
    the backward rebuilds the normalized outer product per j block.
    Fallbacks mirror fused_triangle_mult (ref.outer_product_mean_ref).
    """
    if not fused_opm_supported(a.shape[-1], w.shape[-1], a.dtype):
        return ref.outer_product_mean_ref(a, b_full, mask_a, mask_b, w, bias)
    return _opm_op(tile, kernel_leg("opm"), a, b_full, mask_a, mask_b, w,
                   bias)


# ---------------------------------------------------------------------------
# layer norm
# ---------------------------------------------------------------------------


def _ln_impl(eps, x, gamma, beta):
    # The public layer_norm wrapper routes the oracle leg (Pallas inactive /
    # over-envelope C) before flattening; only the kernel leg reaches here.
    return layer_norm_pallas(x, gamma, beta, eps=eps,
                             interpret=_interpret_for(kernel_leg("layer_norm")))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ln_op(eps, x, gamma, beta):
    return _ln_impl(eps, x, gamma, beta)


def _ln_fwd(eps, x, gamma, beta):
    return _ln_impl(eps, x, gamma, beta), (x, gamma)


def _ln_bwd(eps, res, g):
    x, gamma = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (xf - mean) * inv
    lead = tuple(range(x.ndim - 1))
    dgamma = jnp.sum(gf * xhat, axis=lead)
    dbeta = jnp.sum(gf, axis=lead)
    gg = gf * gamma.astype(jnp.float32)
    dx = inv * (
        gg
        - jnp.mean(gg, axis=-1, keepdims=True)
        - xhat * jnp.mean(gg * xhat, axis=-1, keepdims=True)
    )
    return dx.astype(x.dtype), dgamma.astype(gamma.dtype), dbeta.astype(gamma.dtype)


_ln_op.defvjp(_ln_fwd, _ln_bwd)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last axis; any leading shape.

    The Pallas leg is rank-polymorphic for 2D-4D inputs (grid over the
    leading dims, no row-flatten) so mesh-sharded (B, G, ...) leading dims
    stay unmerged under GSPMD — same contract as the oracle leg. Only 1D /
    5D+ shapes (outside the Evoformer layouts) reshape."""
    c = x.shape[-1]
    if not _use_pallas(kernel_leg("layer_norm")) or c > _MAX_NORM_C:
        # Oracle path without flattening (see bias_sigmoid_mul): keeps
        # mesh-sharded leading dims unmerged under GSPMD.
        return ref.layer_norm_ref(x, gamma, beta, eps)
    if 2 <= x.ndim <= 4:
        return _ln_op(eps, x, gamma, beta)
    xb = x.reshape((-1, c))
    return _ln_op(eps, xb, gamma, beta).reshape(x.shape)


# ---------------------------------------------------------------------------
# bias + sigmoid + mul (gating)
# ---------------------------------------------------------------------------


def _bsm_impl(g, bg, v):
    # The public bias_sigmoid_mul wrapper routes the oracle leg before
    # flattening; only the kernel leg reaches here.
    return bias_sigmoid_mul_pallas(
        g, bg, v, interpret=_interpret_for(kernel_leg("elementwise")))


@jax.custom_vjp
def _bsm_op(g, bg, v):
    return _bsm_impl(g, bg, v)


def _bsm_fwd(g, bg, v):
    return _bsm_impl(g, bg, v), (g, bg, v)


def _bsm_bwd(res, grad):
    g, bg, v = res
    gradf = grad.astype(jnp.float32)
    s = jax.nn.sigmoid(g.astype(jnp.float32) + bg.astype(jnp.float32))
    dv = (gradf * s).astype(v.dtype)
    dg_f = gradf * v.astype(jnp.float32) * s * (1.0 - s)
    dg = dg_f.astype(g.dtype)
    dbg = dg_f.sum(axis=tuple(range(g.ndim - 1))).astype(bg.dtype)
    return dg, dbg, dv


_bsm_op.defvjp(_bsm_fwd, _bsm_bwd)


def bias_sigmoid_mul(g: jax.Array, bg: jax.Array, v: jax.Array) -> jax.Array:
    """sigmoid(g + bg) * v; g and v share shape (..., C), bg is (C,).

    Rank-polymorphic Pallas leg for 2D-4D inputs (grid over the leading
    dims): no row-flatten, so mesh-sharded leading dims stay unmerged under
    GSPMD — matching the oracle leg."""
    c = g.shape[-1]
    if not _use_pallas(kernel_leg("elementwise")) or c > _MAX_NORM_C:
        # Oracle path without flattening: reshaping (B, G, ...) to rows would
        # merge mesh-sharded dims under GSPMD and force a resharding copy of
        # the whole tensor (same note as fused_softmax 5D / bias_dropout_add).
        return ref.bias_sigmoid_mul_ref(g, bg, v)
    if 2 <= g.ndim <= 4:
        return _bsm_op(g, bg, v)
    out = _bsm_op(g.reshape((-1, c)), bg, v.reshape((-1, c)))
    return out.reshape(v.shape)


# ---------------------------------------------------------------------------
# bias + dropout + add (residual)
# ---------------------------------------------------------------------------


def _bda_impl(rate, x, b, residual, keep):
    c = x.shape[-1]
    leg = kernel_leg("elementwise")
    if not _use_pallas(leg) or c > _MAX_NORM_C:
        return ref.bias_dropout_add_ref(x, b, residual,
                                        keep if rate > 0.0 else None, rate)
    return bias_dropout_add_pallas(x, b, residual, keep, rate=rate,
                                   interpret=_interpret_for(leg))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _bda_op(rate, x, b, residual, keep):
    return _bda_impl(rate, x, b, residual, keep)


def _bda_fwd(rate, x, b, residual, keep):
    return _bda_impl(rate, x, b, residual, keep), (keep,)


def _bda_bwd(rate, res, g):
    (keep,) = res
    gf = g.astype(jnp.float32)
    if rate > 0.0:
        dx_f = gf * keep / (1.0 - rate)
    else:
        dx_f = gf
    return (dx_f.astype(g.dtype), dx_f.sum(axis=0).astype(g.dtype), g,
            jnp.zeros_like(keep))


_bda_op.defvjp(_bda_fwd, _bda_bwd)


def bias_dropout_add(
    x: jax.Array,
    b: jax.Array | None,
    residual: jax.Array,
    rate: float = 0.0,
    rng: jax.Array | None = None,
    shared_axes: tuple[int, ...] = (),
) -> jax.Array:
    """residual + dropout(x + b, rate); rng=None or rate=0 disables dropout.

    ``b=None`` means no bias term (the Evoformer residual adds — the update's
    output projection already carries its bias).

    ``shared_axes``: axes of ``x`` along which the dropout mask is SHARED
    (AlphaFold row/column dropout: one Bernoulli draw at the reduced shape,
    broadcast along the named axes). The scale/mask/add still run in one
    fused HBM pass.
    """
    c = x.shape[-1]
    if b is None and (rng is None or rate == 0.0):
        # Pure residual add: no bias operand, no dropout mask. XLA fuses the
        # fp32-accumulate add chain into one HBM pass on its own; running the
        # kernel here would stream an all-ones keep mask and a zero bias for
        # nothing. Same math as the kernel epilogue (fp32 add, cast back).
        return (x.astype(jnp.float32)
                + residual.astype(jnp.float32)).astype(residual.dtype)
    keep_full = None
    eff_rate = 0.0
    if rng is not None and rate > 0.0:
        shape = list(x.shape)
        for ax in shared_axes:
            shape[ax] = 1
        keep_full = jnp.broadcast_to(
            jax.random.bernoulli(rng, 1.0 - rate, tuple(shape)), x.shape
        ).astype(jnp.float32)
        eff_rate = rate
    if b is None:
        b = jnp.zeros((c,), x.dtype)
    if not _use_pallas(kernel_leg("elementwise")) or c > _MAX_NORM_C:
        # Oracle path without flattening: reshaping (B, G, ...) to rows would
        # merge mesh-sharded dims under GSPMD (same note as fused_softmax 5D).
        return ref.bias_dropout_add_ref(x, b, residual, keep_full, eff_rate)
    xb = x.reshape((-1, c))
    rb = residual.reshape((-1, c))
    keep = (keep_full.reshape((-1, c)) if keep_full is not None
            else jnp.ones_like(xb, dtype=jnp.float32))
    out = _bda_op(eff_rate, xb, b, rb, keep)
    return out.reshape(residual.shape)
