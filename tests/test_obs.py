"""Observability suite: unscoped no-op bit-identity for the instrumented
engine and train loop, span nesting/exception safety, the jax-aware
compile-vs-execute timer split, deterministic event ordering, the
trace-cache-miss (plan-hash churn) detector, JSONL schema round-trips, and
a chaos-sweep reconciliation proving the lifecycle event stream exactly
accounts for every injected fault's retry/degradation/quarantine/failure."""
import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.exec.plan import preset
from repro.obs import (
    REQUEST_PHASES,
    TERMINAL_PHASES,
    Tracer,
    aggregate,
    current_tracer,
    hardware_efficiency,
    quantiles,
    read_jsonl,
    reconcile,
    render_report,
    use_tracer,
    validate_bench,
    validate_events,
)
from repro.obs import trace as obs
from repro.resilience import InjectedFault, RetryPolicy, inject_faults
from repro.train.loop import instrument_train_step, make_train_step

from test_resilience import (  # noqa: F401  (setup is a fixture)
    _random_specs,
    make_prompts,
    run_engine,
    setup,
)


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------


def test_unscoped_hooks_are_noops():
    assert current_tracer() is None
    with obs.span("free"):                       # null context, no tracer
        pass
    obs.emit("gauge", "nobody")
    obs.count("nothing")
    obs.gauge("nothing", 1)
    assert obs.timed_call("direct", lambda x: x + 1, 41) == 42
    assert current_tracer() is None


def test_use_tracer_scoping_nested_and_exception_safe():
    with use_tracer() as outer:
        assert current_tracer() is outer
        inner_tr = Tracer()
        with use_tracer(inner_tr):
            assert current_tracer() is inner_tr
        assert current_tracer() is outer
        with pytest.raises(RuntimeError, match="boom"):
            with use_tracer():
                raise RuntimeError("boom")
        assert current_tracer() is outer         # restored despite the raise
    assert current_tracer() is None
    with pytest.raises(TypeError):
        with use_tracer("not a tracer"):
            pass


def test_span_nesting_parent_ids_and_error_status():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("mid"):
            with tr.span("leaf"):
                pass
        with pytest.raises(ValueError, match="bad"):
            with tr.span("broken"):
                raise ValueError("bad")
        with tr.span("after"):                   # stack restored post-raise
            pass
    spans = {e["name"]: e for e in tr.events if e["kind"] == "span"}
    assert spans["outer"]["parent_id"] is None
    assert spans["mid"]["parent_id"] == spans["outer"]["span_id"]
    assert spans["leaf"]["parent_id"] == spans["mid"]["span_id"]
    assert spans["broken"]["parent_id"] == spans["outer"]["span_id"]
    assert spans["after"]["parent_id"] == spans["outer"]["span_id"]
    assert spans["broken"]["status"] == "error"
    assert spans["after"]["status"] == "ok"
    # children close before parents, and every span's interval nests inside
    # its parent's
    assert tr.events[-1]["name"] == "outer"
    for name in ("mid", "leaf", "broken", "after"):
        ev, parent = spans[name], spans[
            "outer" if name != "leaf" else "mid"]
        assert ev["t_start_ns"] >= parent["t_start_ns"]
        assert ev["t_start_ns"] + ev["dur_ns"] <= \
            parent["t_start_ns"] + parent["dur_ns"]
    assert not validate_events(tr.events)


def test_counters_accumulate_and_gauges_record():
    tr = Tracer()
    tr.count("tokens")
    tr.count("tokens", 2.0)
    tr.gauge("depth", 7, step=1)
    assert tr.counters == {"tokens": 3.0}
    counter_events = [e for e in tr.events if e["kind"] == "counter"]
    assert [e["value"] for e in counter_events] == [1.0, 3.0]
    (g,) = [e for e in tr.events if e["kind"] == "gauge"]
    assert g["value"] == 7 and g["attrs"]["step"] == 1


def test_timed_call_separates_compile_from_execute():
    tr = Tracer()

    @jax.jit
    def f(x):
        return (x * 2.0).sum()

    x = jnp.ones((256, 256))
    tr.timed_call("f", f, x)                     # cold: trace + compile
    tr.timed_call("f", f, x)                     # warm: enqueue only
    cold, warm = [e for e in tr.events if e["kind"] == "span"]
    for ev in (cold, warm):
        assert ev["attrs"]["dispatch_ns"] >= 0
        assert ev["attrs"]["block_ns"] >= 0
        assert ev["dur_ns"] >= ev["attrs"]["dispatch_ns"]
    # the cold call's host dispatch carries the compile; warm is orders of
    # magnitude cheaper (10x is a very loose bound for a jit compile)
    assert cold["attrs"]["dispatch_ns"] > 10 * warm["attrs"]["dispatch_ns"]


def test_define_interns_values_deterministically():
    tr = Tracer()
    a = preset("default").to_dict()
    b = preset("oracle").to_dict()
    assert tr.define("plan", a) == "plan:0"
    assert tr.define("plan", b) == "plan:1"
    assert tr.define("plan", a) == "plan:0"      # stable on re-intern
    defs = [e for e in tr.events if e["kind"] == "def"]
    assert [d["name"] for d in defs] == ["plan:0", "plan:1"]  # emitted once
    assert defs[0]["value"] == a


def test_jit_entry_counts_plan_hash_churn():
    tr = Tracer()
    assert tr.jit_entry("decode", "plan:0") is True     # expected trace
    assert tr.jit_entry("decode", "plan:0") is False    # hit
    assert tr.jit_entry("decode", "plan:1") is True     # churn!
    assert tr.jit_entry("prefill", "plan:0") is True    # new site: expected
    assert tr.counters.get("trace_cache_miss") == 1.0
    assert [e["cache"] for e in tr.events if e["kind"] == "jit_entry"] == \
        ["miss", "hit", "miss", "miss"]


def test_jsonl_round_trip_resolves_lazy_values(tmp_path):
    tr = Tracer()
    tr.emit("train_step", "train_step", step=1, dur_ns=10, tokens=None,
            metrics={"loss": jnp.float32(1.5)})      # device array: lazy
    path = tmp_path / "events.jsonl"
    assert tr.dump_jsonl(str(path)) == 1
    (ev,) = read_jsonl(str(path))
    assert ev["metrics"]["loss"] == 1.5              # plain float now
    assert not validate_events([ev])
    buf = io.StringIO()
    tr.dump_jsonl(buf)
    assert json.loads(buf.getvalue()) == ev


# ---------------------------------------------------------------------------
# Schema validation
# ---------------------------------------------------------------------------


def test_validator_rejects_malformed_events():
    ok = {"seq": 0, "t_ns": 1, "kind": "gauge", "name": "g", "value": 1,
          "attrs": {}}
    assert not validate_events([ok])
    assert validate_events([{**ok, "kind": "nope"}])        # unknown kind
    assert validate_events([{**ok, "extra": 1}])            # undeclared field
    bad_phase = {"seq": 0, "t_ns": 1, "kind": "request", "name": "vanished",
                 "uid": 1, "attrs": {}}
    assert validate_events([bad_phase])
    missing = dict(ok)
    del missing["value"]
    assert validate_events([missing])
    assert validate_events([ok, ok])                        # seq not increasing


def test_validate_bench_schema():
    row = {"preset": "default", "plan": preset("default").to_dict(),
           "requests": 4, "tokens": 12.0, "wall_s": 1.0,
           "tokens_per_s": 12.0,
           "latency_ms": {"p50": 1.0, "p95": 2.0, "p99": 3.0},
           "occupancy_mean": 2.0, "jit_entries": {}}
    assert not validate_bench({"schema": 1, "rows": [row]})
    assert validate_bench({"schema": 99, "rows": [row]})
    assert validate_bench({"schema": 1, "rows": []})
    assert validate_bench({"schema": 1, "rows": [{**row, "plan": "hash"}]})
    no_lat = {**row, "latency_ms": {"p50": 1.0}}
    assert validate_bench({"schema": 1, "rows": [no_lat]})


def test_quantiles_and_reconcile_units():
    assert quantiles([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    q = quantiles(list(range(1, 101)))
    assert q["p50"] == 51.0 and q["p95"] == 95.0 and q["p99"] == 99.0

    def req(seq, phase, uid):
        return {"seq": seq, "t_ns": seq, "kind": "request", "name": phase,
                "uid": uid, "attrs": {}}

    good = [req(0, "queued", 1), req(1, "admitted", 1), req(2, "done", 1)]
    assert not reconcile(good)
    assert reconcile([req(0, "queued", 1)])                 # no terminal
    assert reconcile([req(0, "done", 1)])                   # never queued
    double = good + [req(3, "failed", 1)]                   # two terminals
    assert reconcile(double)


# ---------------------------------------------------------------------------
# Engine instrumentation
# ---------------------------------------------------------------------------


def test_unscoped_engine_run_is_bit_identical(setup):
    """The tracer hooks observe, never steer: a traced run produces the
    same tokens and the same final KV cache, bit for bit, as an untraced
    one (the acceptance criterion's no-op guarantee, in the same style as
    the empty-fault-scope test)."""
    cfg, params = setup
    prompts = make_prompts(3)
    eng_a, reqs_a = run_engine(params, cfg, prompts, max_new=3)
    with use_tracer() as tr:
        eng_b, reqs_b = run_engine(params, cfg, prompts, max_new=3)
    assert len(tr.events) > 0
    for a, b in zip(reqs_a, reqs_b):
        assert a.generated == b.generated and b.status == "done"
    for a, b in zip(jax.tree.leaves(eng_a.cache),
                    jax.tree.leaves(eng_b.cache)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_engine_lifecycle_stream_and_report(setup):
    cfg, params = setup
    with use_tracer() as tr:
        run_engine(params, cfg, make_prompts(4), max_new=3)
    events = tr.events_resolved()
    assert not validate_events(events)
    assert not reconcile(events)
    agg = aggregate(events)
    assert agg["requests"]["phases"]["queued"] == 4
    assert agg["requests"]["phases"]["done"] == 4
    assert agg["counters"]["tokens"] == 12.0
    assert agg["meta"]["param_count"] > 0
    assert {"prefill", "decode", "engine.step", "engine.run"} <= \
        set(agg["spans"])
    # self-time: engine.run's own time excludes its engine.step children
    run_span = agg["spans"]["engine.run"]
    assert run_span["self_ns"] < run_span["total_ns"]
    # roofline cross-reference has both phases, with sane fractions
    eff = hardware_efficiency(agg)
    assert set(eff) == {"decode", "prefill"}
    for phase in eff.values():
        assert 0.0 < phase["efficiency"] <= 1.0
    text = render_report(events)
    assert "exactly one terminal state" in text and "roofline" in text
    # every phase in the stream is a documented one
    assert {e["name"] for e in events if e["kind"] == "request"} <= \
        set(REQUEST_PHASES)


def test_deterministic_event_ordering(setup):
    """Two identical runs produce the same event *sequence* — kind, name,
    uid, and attrs all match position by position (timestamps differ,
    structure must not), and seq is strictly increasing."""
    cfg, params = setup

    def shape(run_events):
        drop = ("t_ns", "t_start_ns", "dur_ns", "dispatch_ns", "block_ns")

        def strip(ev):
            ev = {k: v for k, v in ev.items() if k not in drop}
            if "attrs" in ev:
                ev["attrs"] = {k: v for k, v in ev["attrs"].items()
                               if k not in drop}
            return ev

        return [strip(e) for e in run_events]

    streams = []
    for _ in range(2):
        with use_tracer() as tr:
            run_engine(params, cfg, make_prompts(3), max_new=3)
        streams.append(tr.events_resolved())
    assert shape(streams[0]) == shape(streams[1])
    seqs = [e["seq"] for e in streams[0]]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)


def test_mixed_plan_traffic_trips_churn_detector(setup):
    """Two distinct plans in one engine are *expected* to produce two jit
    entries per site — the detector reports exactly the churn beyond the
    first key, which an all-default engine never shows."""
    cfg, params = setup
    prompts = make_prompts(2)
    with use_tracer() as tr:
        run_engine(params, cfg, prompts, max_new=3,
                   plans=[None, preset("oracle")])
    agg = aggregate(tr.events_resolved())
    assert agg["jit"]["decode"]["distinct_keys"] == 2
    assert agg["counters"]["trace_cache_miss"] >= 1.0
    with use_tracer() as tr2:
        run_engine(params, cfg, prompts, max_new=3)
    agg2 = aggregate(tr2.events_resolved())
    assert agg2["jit"]["decode"]["distinct_keys"] == 1
    assert "trace_cache_miss" not in agg2["counters"]


def test_rejected_submit_emits_typed_event(setup):
    cfg, params = setup
    from repro.resilience import AdmissionError
    from repro.serving.engine import ServingEngine

    with use_tracer() as tr:
        eng = ServingEngine(params, cfg, n_slots=2, max_seq=8)
        with pytest.raises(AdmissionError):
            eng.submit(np.zeros((64,), np.int32))
    (ev,) = [e for e in tr.events if e["kind"] == "request"]
    assert ev["name"] == "rejected" and ev["uid"] is None
    assert ev["attrs"]["reason"] == "over_length"
    assert not reconcile(tr.events_resolved())   # uid-less reject is legal


def test_chaos_sweep_event_stream_reconciles(setup):
    """The acceptance criterion's reconciliation proof: under randomized
    injected-fault schedules, the lifecycle event stream accounts for
    every request (exactly one terminal phase matching Request.status) and
    every fired fault maps to a retried/degraded/quarantined/failed event
    for its target uid."""
    cfg, params = setup
    prompts = make_prompts(4, seed=99)
    plans = [None, preset("oracle"), None, preset("oracle")]
    pol = RetryPolicy(max_attempts=3, backoff=1.0,
                      retryable=lambda e: isinstance(e, InjectedFault))
    fired_total = 0
    for seed in range(8):
        rng = np.random.default_rng(seed)
        with use_tracer() as tr:
            with inject_faults(*_random_specs(rng), seed=seed) as inj:
                eng, reqs = run_engine(params, cfg, prompts, max_new=3,
                                       plans=plans, retry=pol)
        fired_total += inj.total_fired
        events = tr.events_resolved()
        assert not validate_events(events), seed
        assert not reconcile(events), seed
        # exactly one terminal event per uid, and it matches the Request
        terminal = {}
        for ev in events:
            if ev["kind"] == "request" and ev["name"] in TERMINAL_PHASES:
                assert ev["uid"] not in terminal, seed
                terminal[ev["uid"]] = ev["name"]
        assert terminal == {r.uid: r.status for r in reqs}, seed
        # every fired fault shows up in its uid's event stream as a retry,
        # degradation, quarantine, or failure
        routed = {p: {e["uid"] for e in events
                      if e["kind"] == "request" and e["name"] == p}
                  for p in ("retried", "degraded", "quarantined", "failed")}
        for fault in inj.events:
            assert any(fault.uid in routed[p] for p in routed), (seed, fault)
    assert fired_total > 0


# ---------------------------------------------------------------------------
# Train-loop instrumentation
# ---------------------------------------------------------------------------


def _toy_setup(guard=True):
    def loss_fn(params, batch, rng):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    init_state, train_step = make_train_step(
        loss_fn, base_lr=1e-2, warmup_steps=2, total_steps=10,
        guard_nonfinite=guard)
    params = {"w": jnp.ones((4, 2), jnp.float32)}
    rng = np.random.default_rng(0)
    batch = {"x": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
             "y": jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)}
    return init_state(params), train_step, batch


def test_metrics_key_contract_is_never_ragged():
    for guard in (True, False):
        state, step, batch = _toy_setup(guard=guard)
        _, metrics = step(state, batch)
        assert {"loss", "grad_norm", "lr", "nonfinite_skips"} <= set(metrics)
        assert float(metrics["nonfinite_skips"]) == 0.0


def test_unscoped_instrumented_train_step_is_bit_identical():
    state_a, step, batch = _toy_setup()
    state_b = state_a
    jstep = jax.jit(step)
    istep = instrument_train_step(jstep, tokens_per_step=8)
    assert current_tracer() is None
    for _ in range(3):
        state_a, ma = jstep(state_a, batch)
        state_b, mb = istep(state_b, batch)
    np.testing.assert_array_equal(np.asarray(state_a.params["w"]),
                                  np.asarray(state_b.params["w"]))
    assert float(ma["loss"]) == float(mb["loss"])


def test_instrumented_train_step_emits_schema_valid_events():
    state, step, batch = _toy_setup()
    istep = instrument_train_step(jax.jit(step), tokens_per_step=8)
    with use_tracer() as tr:
        for _ in range(4):
            state, _ = istep(state, batch)
    events = tr.events_resolved()
    assert not validate_events(events)
    steps = [e for e in events if e["kind"] == "train_step"]
    assert [e["step"] for e in steps] == [1, 2, 3, 4]
    for ev in steps:
        assert ev["tokens"] == 8
        assert isinstance(ev["metrics"]["loss"], float)
        assert ev["metrics"]["nonfinite_skips"] == 0.0
        assert "lr" not in ev["metrics"]        # only the selected keys ride
    agg = aggregate(events)
    assert agg["train"]["steps"] == 4
    assert agg["train"]["nonfinite_skips"] == 0.0
    assert agg["train"]["tokens"] == 32.0
    assert "train: 4 steps" in render_report(events)
