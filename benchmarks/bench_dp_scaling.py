"""Paper Fig. 11 + Table IV — data-parallel scaling and end-to-end cost.

The DP gradient all-reduce is modeled from the roofline terms (93M fp32 grads
over the ICI ring) against the per-step compute derived from the AlphaFold
dry-run (dryrun_single_pod.json when present, else the analytic model). The
derived quantities reproduce Table IV: overall training time on 256/512 chips
vs the paper's 11-day TPUv3 baseline, and Fig. 11's parallel efficiency.
"""
import json
import os

from benchmarks.common import csv_row
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

PARAMS = 93e6
SAMPLES_INITIAL = 10e6
SAMPLES_FINETUNE = 1.5e6
BATCH = 128


def af_step_flops(n_res, n_seq, d_msa=256, d_pair=128):
    """Per-sample fwd FLOPs (analytic, 48 blocks), x3 for train, x~1.4 for
    recycling average (1.5 extra untrained fwd passes at 1/3 cost each)."""
    msa_lin = n_seq * n_res * (6 * d_msa * d_msa + 8 * d_msa * d_msa)
    pair_lin = n_res * n_res * (10 * d_pair * d_pair + 8 * d_pair * d_pair)
    attn = n_seq * n_res * n_res * d_msa * 4 + 2 * n_res ** 3 * d_pair * 2
    opm = n_seq * n_res * n_res * 32 * 32 * 2
    tri = 2 * n_res ** 3 * 128 * 2
    per_block = 2 * (msa_lin + pair_lin + attn + opm + tri)
    fwd = 48 * per_block
    return fwd * 3.0 * 1.9  # bwd x2 + recycling overhead


def run():
    # per-chip step compute at DAP degree d: batch 128 spread over chips/d
    for phase, (n_res, n_seq, dap) in (
        ("initial", (256, 128, 2)), ("finetune", (384, 512, 4)),
    ):
        f_sample = af_step_flops(n_res, n_seq)
        mfu = 0.35  # attainable fraction of peak for this op mix (paper-like)
        t_sample = f_sample / (PEAK_FLOPS_BF16 * mfu) / dap
        # DP all-reduce of fp32 grads per step over the ring
        t_ar = 2 * PARAMS * 4 / ICI_BW
        for chips in (128, 256, 512):
            dp = chips // dap
            micro = max(1, BATCH // dp)
            t_step = micro * t_sample + t_ar
            eff = (micro * t_sample) / t_step
            csv_row(f"dp_{phase}_{chips}chips_step_s", t_step * 1e6,
                    f"parallel_efficiency={eff:.3f} dap={dap} dp={dp}")
        steps = (SAMPLES_INITIAL if phase == "initial"
                 else SAMPLES_FINETUNE) / BATCH
        chips = 256 if phase == "initial" else 512
        dp = chips // dap
        t_step = max(1, BATCH // dp) * t_sample + t_ar
        days = steps * t_step / 86400
        csv_row(f"tableIV_{phase}_days", days * 86400 * 1e6,
                f"days={days:.2f} chips={chips}")

    # Table IV headline: total vs the paper's 11-day baseline
    t_i = (SAMPLES_INITIAL / BATCH) * (
        max(1, BATCH // (256 // 2)) * af_step_flops(256, 128)
        / (PEAK_FLOPS_BF16 * 0.35) / 2 + 2 * PARAMS * 4 / ICI_BW)
    t_f = (SAMPLES_FINETUNE / BATCH) * (
        max(1, BATCH // (512 // 4)) * af_step_flops(384, 512)
        / (PEAK_FLOPS_BF16 * 0.35) / 4 + 2 * PARAMS * 4 / ICI_BW)
    total_days = (t_i + t_f) / 86400
    csv_row("tableIV_total_days", total_days * 86400 * 1e6,
            f"days={total_days:.2f} paper_alphafold=11d paper_fastfold=2.81d "
            f"speedup_vs_11d={11 / total_days:.1f}x")

    # if the dry-run table exists, report the measured roofline step time
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_single_pod.json")
    if os.path.exists(path):
        with open(path) as f:
            recs = json.load(f)
        for rec in recs:
            if rec.get("arch", "").startswith("alphafold") and \
                    rec.get("status") == "ok":
                r = rec["roofline"]
                t = max(r["t_compute_s"], r["t_memory_s"],
                        r["t_collective_s"])
                csv_row(f"dryrun_{rec['arch']}_roofline_step_s", t * 1e6,
                        f"bottleneck={r['bottleneck']}")


if __name__ == "__main__":
    run()
