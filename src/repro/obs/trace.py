"""Structured tracing and metrics, contextvar-scoped like ``use_plan`` and
``inject_faults``: zero overhead and zero behavior change when no tracer is
scoped (every module-level hook is one contextvar read returning a no-op),
one in-memory event stream when one is.

    from repro.obs import use_tracer

    with use_tracer() as tr:
        engine.run()                       # engine emits lifecycle events
    tr.dump_jsonl("run.jsonl")
    # python -m repro.obs report run.jsonl

Clocks: every timestamp is ``time.perf_counter_ns`` relative to the
tracer's start (monotonic — never wall clock, so events order correctly
across NTP steps and the stream is diffable across runs up to durations).
Events additionally carry a ``seq`` number assigned at emit time, which IS
the deterministic ordering key: two runs of the same deterministic workload
produce the same event sequence (kinds/names/attrs), differing only in the
``*_ns`` fields.

The jax-aware timer (``timed_call``) separates host dispatch from device
execution via ``block_until_ready``: ``dispatch_ns`` is the host time for
the call to return (on a cold jit cache this is dominated by trace+compile
time; warm it is the enqueue cost), ``block_ns`` is the wait for the device
to finish (the execute time). The split is recorded per call, so the first
call's dispatch spike is the compile cost of that (fn, shapes, plan) entry.

Trace-cache-miss detection: instrumented jit sites call
``jit_entry(site, key)`` with a stable key (the serialized ExecutionPlan).
The first distinct key per site is the expected trace; every ADDITIONAL
distinct key increments the ``trace_cache_miss`` counter — plan-hash churn
(distinct plans silently multiplying jit entries, the regression the
ExecutionPlan hashability contract worries about) shows up as a counter
instead of an invisible compile stall.

Values stored in events may be device arrays (the train-step metrics path
records them *without* forcing a host sync); they are resolved to floats
only when the tracer serializes (``events_resolved``/``dump_jsonl``) — off
the hot path by construction.

This module imports no jax (the ``timed_call`` import is local) and is
single-thread-per-tracer by design: the two instrumented loops (the serving
engine and the train step loop) are host-side sequential loops.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager, nullcontext
from contextvars import ContextVar
from typing import Any, Callable, Optional


def monotonic_ns() -> int:
    """The obs clock: monotonic, ns. Exposed so host-side step loops (e.g.
    ``train/loop.instrument_train_step``) time through the sanctioned obs
    entry point instead of reading ``time.*`` in traced modules (lint R003).
    """
    return time.perf_counter_ns()


def json_safe(v: Any) -> Any:
    """Resolve a recorded value for serialization. Scalars (including device
    arrays recorded lazily) become floats — THIS is where any deferred
    device transfer happens, never at emit time."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {k: json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [json_safe(x) for x in v]
    try:
        return float(v)
    except (TypeError, ValueError):
        return repr(v)


class Tracer:
    """In-memory event stream + counters. Build one per scenario (like a
    FaultInjector) and scope it with ``use_tracer``; see the module
    docstring of ``repro/obs/__init__.py`` for the full event schema."""

    def __init__(self, *, clock: Callable[[], int] = monotonic_ns):
        self._clock = clock
        self._t0 = clock()
        self._seq = 0
        self.events: list[dict] = []
        self.counters: dict[str, float] = {}
        self._span_stack: list[int] = []
        self._next_span = 0
        self._defs: dict[str, str] = {}          # interned value -> label
        self._def_counts: dict[str, int] = {}    # kind -> next index
        self._jit_keys: dict[str, dict[str, int]] = {}

    # -- core -------------------------------------------------------------

    def _now(self) -> int:
        return self._clock() - self._t0

    def emit(self, kind: str, name: str, **fields) -> dict:
        ev = {"seq": self._seq, "t_ns": self._now(), "kind": kind,
              "name": name, **fields}
        self._seq += 1
        self.events.append(ev)
        return ev

    # -- spans ------------------------------------------------------------

    @contextmanager
    def span(self, name: str, **attrs):
        """Nestable span on the monotonic clock. Exception-safe: the span
        event is emitted (``status="error"``) and the stack restored even
        when the body raises; the exception propagates."""
        span_id = self._next_span
        self._next_span += 1
        parent = self._span_stack[-1] if self._span_stack else None
        self._span_stack.append(span_id)
        t0 = self._now()
        status = "ok"
        try:
            yield span_id
        # status-only observer: re-raises unconditionally, so the typed
        # fault hierarchy passes through untouched
        # repro-lint: disable=R002
        except BaseException:
            status = "error"
            raise
        finally:
            self._span_stack.pop()
            self.emit("span", name, span_id=span_id, parent_id=parent,
                      t_start_ns=t0, dur_ns=self._now() - t0, status=status,
                      attrs=dict(attrs))

    def timed_call(self, name: str, fn, *args,
                   attrs: Optional[dict] = None, **kw):
        """Call ``fn`` under a leaf span with the jax-aware dispatch/execute
        split (see module docstring). Adds one ``block_until_ready`` host
        sync — use on paths that already sync each step (the engine samples
        tokens on the host every step), not on fire-and-forget hot paths."""
        import jax  # local: this module stays importable without a backend

        span_id = self._next_span
        self._next_span += 1
        parent = self._span_stack[-1] if self._span_stack else None
        t0 = self._now()
        out = fn(*args, **kw)
        t1 = self._now()
        jax.block_until_ready(out)
        t2 = self._now()
        self.emit("span", name, span_id=span_id, parent_id=parent,
                  t_start_ns=t0, dur_ns=t2 - t0, status="ok",
                  attrs={**(attrs or {}),
                         "dispatch_ns": t1 - t0, "block_ns": t2 - t1})
        return out

    # -- metrics ----------------------------------------------------------

    def count(self, name: str, delta: float = 1.0, **attrs) -> float:
        value = self.counters.get(name, 0.0) + delta
        self.counters[name] = value
        self.emit("counter", name, delta=delta, value=value,
                  attrs=dict(attrs))
        return value

    def gauge(self, name: str, value, **attrs):
        self.emit("gauge", name, value=value, attrs=dict(attrs))

    # -- interning + jit-entry tracking -----------------------------------

    def define(self, kind: str, value) -> str:
        """Intern ``value`` (JSON-safe) under a deterministic ``kind:N``
        label, emitting one ``def`` event the first time. Events then carry
        the short label instead of repeating the full value (e.g. the
        serialized ExecutionPlan) on every request."""
        key = kind + "\x00" + (value if isinstance(value, str)
                               else json.dumps(value, sort_keys=True))
        label = self._defs.get(key)
        if label is None:
            idx = self._def_counts.get(kind, 0)
            self._def_counts[kind] = idx + 1
            label = f"{kind}:{idx}"
            self._defs[key] = label
            self.emit("def", label, value=value)
        return label

    def jit_entry(self, site: str, key: str) -> bool:
        """Record one call through a plan-keyed jit site. Returns True on a
        trace-cache miss (first sighting of ``key`` at ``site``); misses
        beyond the first per site bump the ``trace_cache_miss`` counter —
        the plan-hash-churn detector."""
        seen = self._jit_keys.setdefault(site, {})
        miss = key not in seen
        if miss:
            seen[key] = len(seen)
        self.emit("jit_entry", site, key=key,
                  cache="miss" if miss else "hit")
        if miss and len(seen) > 1:
            self.count("trace_cache_miss", site=site)
        return miss

    # -- serialization ----------------------------------------------------

    def events_resolved(self) -> list[dict]:
        """Events with every lazily-recorded value resolved to JSON-safe
        types (forces any deferred device transfers — call off the hot
        path)."""
        return [json_safe(e) for e in self.events]

    def dump_jsonl(self, path_or_file) -> int:
        """Write the resolved event stream as JSONL (one event per line,
        the documented stable schema). Returns the event count."""
        events = self.events_resolved()
        if hasattr(path_or_file, "write"):
            for e in events:
                path_or_file.write(json.dumps(e, sort_keys=True) + "\n")
        else:
            with open(path_or_file, "w", encoding="utf-8") as fh:
                for e in events:
                    fh.write(json.dumps(e, sort_keys=True) + "\n")
        return len(events)


# ---------------------------------------------------------------------------
# Scoping (mirrors exec.plan.use_plan / resilience.inject_faults)
# ---------------------------------------------------------------------------

_TRACER: ContextVar[Optional[Tracer]] = ContextVar("repro_tracer",
                                                   default=None)


def current_tracer() -> Optional[Tracer]:
    """The innermost ``use_tracer`` scope's tracer, else None."""
    return _TRACER.get()


@contextmanager
def use_tracer(tracer: Optional[Tracer] = None):
    """Scope a Tracer (re-entrant, exception-safe restore). Pass a pre-built
    Tracer to accumulate several scopes into one stream, or nothing to get
    a fresh one."""
    tr = tracer if tracer is not None else Tracer()
    if not isinstance(tr, Tracer):
        raise TypeError(f"use_tracer expects a Tracer, got {tr!r}")
    token = _TRACER.set(tr)
    try:
        yield tr
    finally:
        _TRACER.reset(token)


# ---------------------------------------------------------------------------
# Module-level no-op hooks (the instrumentation surface: one contextvar
# read when unscoped, like resilience.fire)
# ---------------------------------------------------------------------------

_NULL_SPAN = nullcontext(None)


def span(name: str, **attrs):
    """A tracer span, or a reusable null context when unscoped."""
    tr = _TRACER.get()
    if tr is None:
        return _NULL_SPAN
    return tr.span(name, **attrs)


def emit(kind: str, name: str, **fields) -> None:
    tr = _TRACER.get()
    if tr is not None:
        tr.emit(kind, name, **fields)


def count(name: str, delta: float = 1.0, **attrs) -> None:
    tr = _TRACER.get()
    if tr is not None:
        tr.count(name, delta, **attrs)


def gauge(name: str, value, **attrs) -> None:
    tr = _TRACER.get()
    if tr is not None:
        tr.gauge(name, value, **attrs)


def timed_call(name: str, fn, *args, attrs: Optional[dict] = None, **kw):
    """``fn(*args, **kw)`` — direct call when unscoped, dispatch/execute
    timed span when a tracer is active."""
    tr = _TRACER.get()
    if tr is None:
        return fn(*args, **kw)
    return tr.timed_call(name, fn, *args, attrs=attrs, **kw)
