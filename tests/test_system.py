"""End-to-end behaviour: the paper's system trains (loss decreases) on both
workload families, and the Duality-Async overlap report sees the collectives."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.alphafold import SMOKE
from repro.core.alphafold import alphafold_train_loss, init_alphafold
from repro.core.duality import overlap_report
from repro.data import lm_batches, protein_batches
from repro.models.decoder import init_model, lm_loss
from repro.train.loop import make_train_step


def test_alphafold_training_loss_decreases():
    params = init_alphafold(jax.random.PRNGKey(0), SMOKE)
    gen = protein_batches(batch=2, n_seq=6, n_res=12, seed=0)
    init_state, train_step = make_train_step(
        lambda p, b, r: alphafold_train_loss(p, b, SMOKE, rng=r),
        base_lr=1e-3, warmup_steps=5, total_steps=500)
    state = init_state(params)
    step = jax.jit(train_step)
    losses = []
    pb = next(gen)
    batch = {k: jnp.asarray(getattr(pb, k)) for k in
             ("msa", "msa_mask", "residue_index", "aatype", "seq_mask",
              "pseudo_beta", "bert_mask", "true_msa")}
    for i in range(25):
        state, metrics = step(state, batch, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_lm_training_loss_decreases():
    cfg = get_config("qwen2-1.5b", reduced_variant=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    gen = lm_batches(vocab=cfg.vocab, batch=4, seq=32, seed=0)
    init_state, train_step = make_train_step(
        lambda p, b, r: lm_loss(p, b, cfg),
        base_lr=3e-3, warmup_steps=5, total_steps=500)
    state = init_state(params)
    step = jax.jit(train_step)
    losses = []
    for i in range(25):
        lb = next(gen)
        batch = {"tokens": jnp.asarray(lb.tokens),
                 "targets": jnp.asarray(lb.targets),
                 "mask": jnp.asarray(lb.mask)}
        state, metrics = step(state, batch, None)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_overlap_report_parses_async_pairs():
    txt = """
%foo (a: f32[4]) -> f32[4] {
  %ag = f32[8]{0} all-gather-start(%a), dimensions={0}
  %d = f32[4]{0} dot(%a, %a), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  %done = f32[8]{0} all-gather-done(%ag)
}
"""
    rep = overlap_report(txt)
    assert rep["pairs"] == 1
    assert rep["pairs_with_compute_between"] == 1
