"""RetryPolicy: capped exponential backoff with deterministic jitter.

One reusable policy object serves every retry consumer: the serving
engine's ``submit(..., retry=...)`` (which interprets delays as *engine
steps*, so tests never sleep), and direct ``policy.call(fn)`` wrapping for
host-side stages (checkpoint saves, feature-pipeline RPCs), where delays
are seconds through an injectable ``sleep``.
"""
from __future__ import annotations

import math
import random
import time
from dataclasses import dataclass, field
from typing import Callable


def default_retryable(err: BaseException) -> bool:
    """Transient-by-construction faults retry by default; everything else
    (OOM -> degradation ladder, non-finite -> quarantine, real bugs ->
    propagate) needs an explicit opt-in predicate."""
    from repro.resilience.faults import StageTimeout, TransientDecodeFault

    return isinstance(err, (TransientDecodeFault, StageTimeout))


@dataclass(frozen=True)
class RetryPolicy:
    """``max_attempts`` counts total tries (1 = no retry). Delay before
    retry #k (after attempt k failed) is
    ``min(backoff * multiplier**(k-1), max_backoff)``, stretched by up to
    ``+/- jitter`` (a fraction), drawn deterministically from
    ``(seed, attempt)``."""

    max_attempts: int = 3
    backoff: float = 1.0
    multiplier: float = 2.0
    max_backoff: float = 30.0
    jitter: float = 0.0
    retryable: Callable[[BaseException], bool] = field(
        default=default_retryable)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("RetryPolicy.max_attempts must be >= 1")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("RetryPolicy.jitter must be in [0, 1)")

    def delay(self, attempt: int, *, seed: int = 0) -> float:
        """Backoff before retrying after (1-based) ``attempt`` failed."""
        d = min(self.backoff * self.multiplier ** max(attempt - 1, 0),
                self.max_backoff)
        if self.jitter:
            u = random.Random(f"{seed}:{attempt}").random()  # deterministic
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return max(d, 0.0)

    def delay_steps(self, attempt: int, *, seed: int = 0) -> int:
        """The delay quantized to engine steps (>= 1: a retry is never
        eligible in the same step it failed)."""
        return max(1, math.ceil(self.delay(attempt, seed=seed)))

    def should_retry(self, err: BaseException, attempt: int) -> bool:
        """True when (1-based) ``attempt`` failed with ``err`` and another
        try is allowed."""
        return attempt < self.max_attempts and bool(self.retryable(err))

    def call(self, fn: Callable, *args, sleep: Callable = time.sleep,
             seed: int = 0, **kw):
        """Run ``fn`` under this policy. Retryable failures back off via
        ``sleep`` (injectable — tests pass a recorder); the final failure
        re-raises the original error."""
        attempt = 0
        while True:
            attempt += 1
            try:
                return fn(*args, **kw)
            except Exception as err:
                if not self.should_retry(err, attempt):
                    raise
                sleep(self.delay(attempt, seed=seed))
