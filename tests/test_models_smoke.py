"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
same-family config, one forward/train step on CPU asserting shapes + no NaN,
plus prefill+decode == full-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.decoder import init_model, lm_loss, model_forward

ARCHS = list_archs()


def make_batch(cfg, B=2, S=24, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.modality and cfg.modality.n_prefix_tokens:
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (B, cfg.modality.n_prefix_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_is_reduced(arch):
    cfg = get_config(arch, reduced_variant=True)
    assert cfg.n_layers <= 2 or sum(c for _, c in cfg.resolved_stages) <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff,
           cfg.vocab)
    assert got == expected
    assert sum(c for _, c in cfg.resolved_stages) == cfg.n_layers


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced_variant=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    out = model_forward(params, batch["tokens"], cfg, mode="train",
                        prefix_embeds=batch.get("prefix_embeds"))
    P = (cfg.modality.n_prefix_tokens if cfg.modality else 0)
    assert out["logits"].shape == (2, 24 + P, cfg.vocab)
    assert not bool(jnp.isnan(out["logits"]).any())

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm_loss(p, batch, cfg), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, reduced_variant=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S0, S1 = 2, 16, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S0 + S1), 0,
                              cfg.vocab)
    pe = None
    if cfg.modality and cfg.modality.n_prefix_tokens:
        pe = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.modality.n_prefix_tokens, cfg.d_model))
    P = pe.shape[1] if pe is not None else 0
    full = model_forward(params, toks, cfg, mode="train", prefix_embeds=pe,
                         remat=False, compute_dtype=jnp.float32)["logits"]
    out = model_forward(params, toks[:, :S0], cfg, mode="prefill",
                        prefix_embeds=pe, max_cache_len=P + S0 + S1,
                        compute_dtype=jnp.float32)
    cache, lengths = out["cache"], jnp.full((B,), P + S0, jnp.int32)
    dec = []
    for t in range(S1):
        o = model_forward(params, toks[:, S0 + t:S0 + t + 1], cfg,
                          mode="decode", cache=cache, lengths=lengths,
                          compute_dtype=jnp.float32)
        cache, lengths = o["cache"], lengths + 1
        dec.append(o["logits"])
    dec = jnp.concatenate(dec, axis=1)
    want = full[:, P + S0:P + S0 + S1]
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    assert float(jnp.max(jnp.abs(dec - want))) / scale < 2e-2
