"""TrainState: fp32 master params + optimizer state + step counter."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jax.Array
    params: dict
    opt_state: object


def make_train_state(params, opt_init) -> TrainState:
    return TrainState(jnp.zeros((), jnp.int32), params, opt_init(params))
