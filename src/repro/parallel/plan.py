"""Sharding plans: (architecture x input-shape x mesh) -> PartitionSpecs.

Parallelism composition (DESIGN.md §4):
  * batch         -> ('pod', 'data')                       [data parallel]
  * sequence/axial-> 'model'                               [DAP, the paper]
  * parameters    -> replicated when the fp32 copy is small (paper-faithful
                     DAP keeps full params per device: AlphaFold, musicgen,
                     xlstm), otherwise sharded over 'model' (ZeRO-3-style,
                     a beyond-paper necessity for the 7B..236B assigned archs)
  * optimizer m/v -> always sharded (ZeRO-1) — the fp32 optimizer state never
                     replicates
  * MoE experts   -> expert axis over 'model' (EP); grouped dispatch keeps
                     routing metadata shard-local
  * KV caches     -> sequence axis over 'model' ('data'+'model' for the
                     batch-1 long_500k shape)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig

# params whose fp32 bytes stay under this replicate (pure DAP, paper-faithful)
REPLICATE_PARAM_BYTES = 2 << 30


def batch_axes(mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _divisible(n: int, axis_size: int) -> bool:
    return n % axis_size == 0 and n >= axis_size


# tensors above this (element count) also shard a second dim over 'data'
# (ZeRO across the full mesh — a 236B fp32 optimizer state cannot live on a
# single mesh axis's worth of shards).
_SECOND_AXIS_ELEMS = 16 << 20


def param_spec(path: str, shape: tuple, mesh, *, stacked: bool) -> P:
    """Sharding rule for one parameter tensor. `stacked` marks a leading
    layer axis (never sharded — it is scanned)."""
    m = mesh.shape["model"]
    d = mesh.shape["data"] * mesh.shape.get("pod", 1)
    zero_axes = (("pod", "data") if "pod" in mesh.shape else ("data",))
    dims: list = [None] * len(shape)
    size = 1
    for s in shape:
        size *= s
    if size < (1 << 16):  # tiny tensors (norms, biases): replicate
        return P(*dims)
    start = 1 if stacked else 0
    model_dim = None
    if "experts" in path and _divisible(shape[start], m):
        model_dim = start  # expert-parallel: shard the expert axis
    else:
        # largest divisible non-stacked dim over 'model'
        for i in sorted(range(start, len(shape)), key=lambda i: -shape[i]):
            if _divisible(shape[i], m):
                model_dim = i
                break
    if model_dim is None:
        return P(*dims)
    dims[model_dim] = "model"
    if size >= _SECOND_AXIS_ELEMS:
        for i in sorted(range(start, len(shape)), key=lambda i: -shape[i]):
            if i != model_dim and _divisible(shape[i], d):
                dims[i] = zero_axes
                break
        else:
            # single shardable dim: ride both axes on it if divisible
            if _divisible(shape[model_dim], m * d):
                dims[model_dim] = zero_axes + ("model",)
    return P(*dims)


def tree_param_specs(params, mesh) -> object:
    """Specs for a full model param pytree (stacked stage params detected by
    path containing 'stages')."""
    def spec_for(path, leaf):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        stacked = "stages" in pstr or "evoformer" in pstr
        return param_spec(pstr, leaf.shape, mesh, stacked=stacked)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def tree_replicated(params) -> object:
    return jax.tree.map(lambda _: P(), params)


def params_fp32_bytes(params) -> int:
    return sum(leaf.size * 4 for leaf in jax.tree.leaves(params))


def model_param_specs(params, mesh, *, force_shard: bool | None = None):
    """Param specs: replicated (paper-faithful DAP) for small models, sharded
    otherwise."""
    shard = (params_fp32_bytes(params) > REPLICATE_PARAM_BYTES
             if force_shard is None else force_shard)
    return tree_param_specs(params, mesh) if shard else tree_replicated(params)


def train_state_specs(state, mesh, param_specs):
    """TrainState sharding: params per plan; m/v ALWAYS sharded (ZeRO-1)."""
    from repro.train.state import TrainState
    mv_specs = tree_param_specs(state.params, mesh)
    return TrainState(
        step=P(),
        params=param_specs,
        opt_state=type(state.opt_state)(
            step=P(), m=mv_specs, v=mv_specs),
    )


# ---------------------------------------------------------------------------
# activations / inputs
# ---------------------------------------------------------------------------

def seq_axes(mesh, shape: ShapeConfig):
    """Mesh axes sharding the sequence dim: DAP 'model'; long_500k rides every
    axis (batch=1 leaves data idle)."""
    if shape.global_batch == 1:
        return batch_axes(mesh) + ("model",)
    return ("model",)


def token_spec(mesh, shape: ShapeConfig) -> P:
    if shape.kind == "decode":
        return P(batch_axes(mesh) if shape.global_batch > 1 else None, None)
    return P(batch_axes(mesh), seq_axes(mesh, shape))


def make_shard_x(mesh, shape: ShapeConfig):
    """Residual-stream constrainer: (B, S, d) pinned to DAP sharding."""
    if shape.kind == "decode":
        spec = P(batch_axes(mesh) if shape.global_batch > 1 else None,
                 None, None)
    else:
        spec = P(batch_axes(mesh), seq_axes(mesh, shape), None)
    sharding = NamedSharding(mesh, spec)

    def constrain(x):
        return jax.lax.with_sharding_constraint(x, sharding)

    return constrain


def cache_specs(cache, mesh, shape: ShapeConfig, cfg: ModelConfig):
    """Decode-cache sharding: stacked (count, B, S, ...) KV caches shard their
    sequence axis; SSM/mLSTM states shard the feature axis."""
    b_ax = batch_axes(mesh) if shape.global_batch > 1 else None
    s_ax = seq_axes(mesh, shape)
    m = mesh.shape["model"]

    def spec_for(path, leaf):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        shp = leaf.shape  # leading dim = stage layer count
        dims = [None] * len(shp)
        dims[1] = b_ax
        if ("k" in pstr.split("/")[-1] or "v" in pstr.split("/")[-1]
                or "c_kv" in pstr or "k_rope" in pstr) and len(shp) >= 3:
            # (count, B, S, ...) — shard seq if long enough
            if _divisible(shp[2], max(m, 2)):
                dims[2] = s_ax
            return P(*dims)
        # states: (count, B, di, n) / (count, B, H, hd[, hd]) / conv
        for i in range(2, len(shp)):
            if _divisible(shp[i], m):
                dims[i] = "model"
                break
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def moe_with_groups(cfg: ModelConfig, mesh) -> ModelConfig:
    """Set MoE dispatch groups to the DAP degree for shard-local routing."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_groups=mesh.shape["model"]))
