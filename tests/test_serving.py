"""Serving engine: batched continuous batching == sequential greedy decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.decoder import init_model, model_forward
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b", reduced_variant=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def ref_greedy(params, cfg, prompt, n):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    outs = []
    for _ in range(n):
        logits = model_forward(params, toks, cfg, mode="train",
                               remat=False)["logits"]
        nxt = int(jnp.argmax(logits[0, -1]))
        outs.append(nxt)
        toks = jnp.concatenate([toks, jnp.asarray([[nxt]], jnp.int32)], 1)
    return outs


def test_engine_matches_sequential_greedy(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, n_slots=3, max_seq=48)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=(6 + i,)) for i in range(5)]
    reqs = [eng.submit(p, max_new_tokens=5) for p in prompts]
    finished = eng.run()
    assert len(finished) == 5
    by_uid = {r.uid: r for r in finished}
    for i, prompt in enumerate(prompts[:3]):
        want = ref_greedy(params, cfg, prompt, 5)
        assert by_uid[i].generated == want


def test_engine_more_requests_than_slots(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=32)
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(rng.integers(0, cfg.vocab, size=(4,)), max_new_tokens=3)
    finished = eng.run()
    assert len(finished) == 6
    assert all(len(r.generated) == 3 for r in finished)


def test_engine_rejects_overlength_prompt(setup):
    """A prompt longer than the KV-cache extent must be rejected at submit()
    — admitting it would clamp decode-time cache writes into the last row."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, n_slots=1, max_seq=16)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(np.zeros((17,), np.int32))
    # boundary: a max_seq-length prompt is admissible (one token from the
    # prefill logits, then the slot is force-finished).
    req = eng.submit(np.random.default_rng(3).integers(0, cfg.vocab,
                                                       size=(16,)),
                     max_new_tokens=8)
    finished = eng.run()
    assert finished == [req] and req.done
    assert len(req.generated) == 1


def test_engine_forces_done_at_max_seq(setup):
    """A slot that reaches max_seq is force-finished instead of decoding
    past the cache: generation stops at the cap and the tokens produced up
    to the cap match unbounded sequential greedy decoding (i.e. no clamped
    cache writes corrupted earlier rows)."""
    cfg, params = setup
    max_seq, plen = 12, 8
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=max_seq)
    prompt = np.random.default_rng(4).integers(0, cfg.vocab, size=(plen,))
    req = eng.submit(prompt, max_new_tokens=50)
    finished = eng.run()
    assert finished == [req] and req.done
    # prefill emits 1 token; decode writes rows plen..max_seq-1 emit the rest
    assert len(req.generated) == max_seq - plen + 1
    want = ref_greedy(params, cfg, prompt, len(req.generated))
    assert req.generated == want


def test_engine_eos_stops_early(setup):
    cfg, params = setup
    prompt = np.random.default_rng(2).integers(0, cfg.vocab, size=(6,))
    eos = ref_greedy(params, cfg, prompt, 2)[1]
    eng = ServingEngine(params, cfg, n_slots=1, max_seq=32)
    eng.submit(prompt, max_new_tokens=10, eos_id=int(eos))
    finished = eng.run()
    assert finished[0].generated[-1] == eos
    assert len(finished[0].generated) <= 2
