from repro.exec import envcompat
envcompat.force_host_device_count(512)  # before jax import: no backend yet
# Kernels stay ENABLED: on a non-TPU backend every op lowers its XLA-native
# leg (ops.kernel_leg) — interpret-mode Pallas (a per-grid-cell loop,
# catastrophic inside a 512-device SPMD program) never runs unless the plan
# asks for interpret mode. In particular the Evoformer attention sites lower
# the shard_map-wrapped fused-attention path (GspmdDist.sharded_attention),
# i.e. the dry-run proves the production DAP x fused-kernel composition —
# no oracle fallback, no merged-(B, G) all-gather.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) combination, and for both the 16x16
single-pod and 2x16x16 multi-pod production meshes:

    with mesh:
        lowered = jax.jit(step_fn, in_shardings=..., out_shardings=...) \
            .lower(*input_specs(arch, shape))
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves it fits 16 GB/chip
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

plus the FastFold/AlphaFold model itself (Initial-Training and Fine-tuning
shapes under DAP). Results are dumped as JSON consumed by
benchmarks/roofline_report.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.json]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import (
    HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh,
)
from repro.models.decoder import init_cache, init_model, lm_loss, model_forward
from repro.parallel import plan
from repro.roofline import analysis
from repro.train.loop import make_train_step


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: long_500k requires a sub-quadratic "
                "path (DESIGN.md §Arch-applicability)")
    return None


def text_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Text tokens; VLM prefix tokens count toward the sequence budget."""
    if cfg.modality and cfg.modality.n_prefix_tokens and shape.kind != "decode":
        return shape.seq_len - cfg.modality.n_prefix_tokens
    return shape.seq_len


# ---------------------------------------------------------------------------
# step builders: return (fn, example_args, in_shardings, out_shardings)
# ---------------------------------------------------------------------------

def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh):
    cfg = plan.moe_with_groups(cfg, mesh)
    b, s = shape.global_batch, text_len(cfg, shape)
    shard_x = plan.make_shard_x(mesh, shape)

    params = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    init_state, train_step = make_train_step(
        lambda p, batch, rng: lm_loss(p, batch, cfg, shard_x=shard_x),
        base_lr=3e-4, total_steps=10_000, weight_decay=0.1,
        state_dtype=jnp.bfloat16 if cfg.opt_state_bf16 else jnp.float32)
    state = jax.eval_shape(lambda: init_state(params))

    p_specs = plan.model_param_specs(params, mesh)
    state_specs = plan.train_state_specs(state, mesh, p_specs)
    tok_spec = plan.token_spec(mesh, shape)
    batch = {
        "tokens": sds((b, s), jnp.int32),
        "targets": sds((b, s), jnp.int32),
        "mask": sds((b, s), jnp.float32),
    }
    batch_specs = {"tokens": tok_spec, "targets": tok_spec, "mask": tok_spec}
    if cfg.modality and cfg.modality.n_prefix_tokens:
        batch["prefix_embeds"] = sds(
            (b, cfg.modality.n_prefix_tokens, cfg.d_model), jnp.bfloat16)
        batch_specs["prefix_embeds"] = P(
            plan.batch_axes(mesh), plan.seq_axes(mesh, shape), None)

    def fn(state, batch):
        new_state, metrics = train_step(state, batch, None)
        return new_state, metrics["loss"]

    in_sh = (jax.tree.map(lambda sp: NamedSharding(mesh, sp), state_specs,
                          is_leaf=lambda x: isinstance(x, P)),
             jax.tree.map(lambda sp: NamedSharding(mesh, sp), batch_specs,
                          is_leaf=lambda x: isinstance(x, P)))
    out_sh = (in_sh[0], NamedSharding(mesh, P()))
    return fn, (state, batch), in_sh, out_sh


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh):
    cfg = plan.moe_with_groups(cfg, mesh)
    b, s = shape.global_batch, text_len(cfg, shape)
    shard_x = plan.make_shard_x(mesh, shape)
    params = jax.eval_shape(
        lambda: plan_cast_bf16(init_model(jax.random.PRNGKey(0), cfg)))
    p_specs = plan.model_param_specs(
        params, mesh,
        force_shard=False if cfg.serve_replicate_params else None)

    args = [sds((b, s), jnp.int32)]
    arg_specs = [plan.token_spec(mesh, shape)]
    prefix = None
    if cfg.modality and cfg.modality.n_prefix_tokens:
        args.append(sds((b, cfg.modality.n_prefix_tokens, cfg.d_model),
                        jnp.bfloat16))
        arg_specs.append(P(plan.batch_axes(mesh),
                           plan.seq_axes(mesh, shape), None))

    def fn(params, tokens, *rest):
        pe = rest[0] if rest else None
        out = model_forward(params, tokens, cfg, mode="prefill",
                            prefix_embeds=pe, shard_x=shard_x,
                            max_cache_len=shape.seq_len)
        return out["logits"][:, -1], out["cache"]

    cache_shapes = jax.eval_shape(lambda: init_cache(cfg, b, shape.seq_len))
    c_specs = plan.cache_specs(cache_shapes, mesh, shape, cfg)
    to_sh = lambda tree: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), tree,
        is_leaf=lambda x: isinstance(x, P))
    in_sh = (to_sh(p_specs), *[NamedSharding(mesh, sp) for sp in arg_specs])
    out_sh = (NamedSharding(mesh, P(plan.batch_axes(mesh), None)),
              to_sh(c_specs))
    return fn, (params, *args), in_sh, out_sh


def build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh):
    cfg = plan.moe_with_groups(cfg, mesh)
    b = shape.global_batch
    shard_x = plan.make_shard_x(mesh, shape)
    params = jax.eval_shape(
        lambda: plan_cast_bf16(init_model(jax.random.PRNGKey(0), cfg)))
    p_specs = plan.model_param_specs(
        params, mesh,
        force_shard=False if cfg.serve_replicate_params else None)
    cache = jax.eval_shape(lambda: init_cache(cfg, b, shape.seq_len))
    c_specs = plan.cache_specs(cache, mesh, shape, cfg)

    tokens = sds((b, 1), jnp.int32)
    lengths = sds((b,), jnp.int32)
    b_ax = plan.batch_axes(mesh) if b > 1 else None

    def fn(params, tokens, cache, lengths):
        out = model_forward(params, tokens, cfg, mode="decode", cache=cache,
                            lengths=lengths, shard_x=shard_x)
        return out["logits"][:, 0], out["cache"]

    to_sh = lambda tree: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), tree,
        is_leaf=lambda x: isinstance(x, P))
    in_sh = (to_sh(p_specs), NamedSharding(mesh, P(b_ax, None)),
             to_sh(c_specs), NamedSharding(mesh, P(b_ax)))
    out_sh = (NamedSharding(mesh, P(b_ax, None)), to_sh(c_specs))
    return fn, (params, tokens, cache, lengths), in_sh, out_sh


def plan_cast_bf16(params):
    return jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}


# ---------------------------------------------------------------------------
# AlphaFold (the paper's own model) under DAP
# ---------------------------------------------------------------------------

def build_alphafold(variant: str, mesh, evo_overrides: dict | None = None):
    from repro.configs import alphafold as afc
    from repro.core.alphafold import alphafold_train_loss, init_alphafold
    from repro.core.dist import GspmdDist, batch_spec

    cfg = afc.FULL
    if evo_overrides:
        cfg = dataclasses.replace(
            cfg, evoformer=dataclasses.replace(cfg.evoformer, **evo_overrides))
    dims = afc.INITIAL_TRAINING if variant == "initial" else afc.FINE_TUNING
    b = dims["batch"]
    s, r = dims["n_seq"], dims["n_res"]
    dist = GspmdDist(mesh=mesh, axis="model")
    bx = batch_spec(mesh)

    batch = {
        "msa": sds((b, s, r), jnp.int32),
        "msa_mask": sds((b, s, r), jnp.float32),
        "residue_index": sds((b, r), jnp.int32),
        "aatype": sds((b, r), jnp.int32),
        "seq_mask": sds((b, r), jnp.float32),
        "pseudo_beta": sds((b, r, 3), jnp.float32),
        "bert_mask": sds((b, s, r), jnp.float32),
        "true_msa": sds((b, s, r), jnp.int32),
    }
    batch_specs = {
        "msa": P(bx, "model", None), "msa_mask": P(bx, "model", None),
        "residue_index": P(bx, None), "aatype": P(bx, None),
        "seq_mask": P(bx, None), "pseudo_beta": P(bx, None, None),
        "bert_mask": P(bx, "model", None), "true_msa": P(bx, "model", None),
    }

    params = jax.eval_shape(
        lambda: init_alphafold(jax.random.PRNGKey(0), cfg))
    init_state, train_step = make_train_step(
        lambda p, bb, rng: alphafold_train_loss(p, bb, cfg, dist=dist),
        base_lr=1e-3, total_steps=10_000)
    state = jax.eval_shape(lambda: init_state(params))
    # paper-faithful DAP: params fully replicated; ZeRO-1 on optimizer m/v
    p_specs = plan.tree_replicated(params)
    state_specs = plan.train_state_specs(state, mesh, p_specs)

    def fn(state, batch):
        new_state, metrics = train_step(state, batch, None)
        return new_state, metrics["loss"]

    to_sh = lambda tree: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), tree,
        is_leaf=lambda x: isinstance(x, P))
    in_sh = (to_sh(state_specs), to_sh(batch_specs))
    out_sh = (in_sh[0], NamedSharding(mesh, P()))
    return fn, (state, batch), in_sh, out_sh


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            overrides: dict | None = None) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rec = {"arch": arch, "shape": shape_name, "chips": chips,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "overrides": overrides or {}}

    if arch.startswith("alphafold"):
        variant = arch.split("-")[1]
        fn, args, in_sh, out_sh = build_alphafold(variant, mesh,
                                                  evo_overrides=overrides)
        cfg = None
        shape = ShapeConfig(arch, 0, 128, "train")
    else:
        cfg = get_config(arch)
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        shape = INPUT_SHAPES[shape_name]
        skip = skip_reason(cfg, shape)
        if skip:
            rec.update({"status": "skipped", "reason": skip})
            return rec
        fn, args, in_sh, out_sh = BUILDERS[shape.kind](cfg, shape, mesh)

    # donate the mutable aggregate (train state / decode cache) — realistic
    # steady-state memory, as a real launcher would run it.
    if arch.startswith("alphafold") or shape.kind == "train":
        donate = (0,)
    elif shape.kind == "decode":
        donate = (2,)
    else:
        donate = ()
    # jax>=0.5 wants jax.set_mesh; older jax uses the Mesh context manager.
    with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # jax 0.4.x returns a one-element list of cost dicts; >=0.5 a plain dict.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    flops, hbm_bytes = analysis.hlo_cost(hlo)
    coll = analysis.parse_collectives(hlo, mesh.shape["model"])
    # the SPMD HLO is the per-device program: parsed quantities are already
    # per-chip, so the roofline denominator uses 1 chip.
    roof = analysis.Roofline(
        flops=flops, hbm_bytes=hbm_bytes, wire_bytes=coll.wire_bytes,
        chips=1, peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, ici_bw=ICI_BW)

    # memory_analysis is per-device under SPMD: live bytes = args (params,
    # optimizer state, caches) + peak temp during execution.
    peak = getattr(mem, "peak_memory_in_bytes", 0) or mem.temp_size_in_bytes
    per_dev_bytes = mem.argument_size_in_bytes + peak
    rec.update({
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": peak,
            "per_device_bytes": per_dev_bytes,
            "fits_16GB": bool(per_dev_bytes <= HBM_BYTES),
        },
        "cost_analysis": {"flops_raw": cost.get("flops", 0.0),
                          "bytes_raw": cost.get("bytes accessed", 0.0)},
        "collectives": {"counts": coll.counts,
                        "payload_bytes": coll.payload_bytes,
                        "wire_bytes": coll.wire_bytes},
        "roofline": roof.as_dict(),
    })
    if cfg is not None:
        from repro.layers.params import count_params
        rec["roofline"]["note"] = ""
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--include-alphafold", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    jobs = []
    if args.all:
        for arch in list_archs():
            for shape in INPUT_SHAPES:
                jobs.append((arch, shape))
        if args.include_alphafold:
            jobs += [("alphafold-initial", "train"),
                     ("alphafold-finetune", "train")]
    else:
        jobs = [(args.arch, args.shape)]

    results = []
    for arch, shape in jobs:
        try:
            rec = run_one(arch, shape, multi_pod=args.multi_pod)
        except Exception as e:  # a failure here is a bug in the system
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f"bottleneck={r['bottleneck']} "
                     f"tc={r['t_compute_s']:.2e} tm={r['t_memory_s']:.2e} "
                     f"tx={r['t_collective_s']:.2e} "
                     f"fits={rec['memory']['fits_16GB']}")
        elif status == "error":
            extra = rec["error"][:160]
        print(f"[{status:7s}] {arch:24s} {shape:12s} {extra}", flush=True)
        results.append(rec)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
