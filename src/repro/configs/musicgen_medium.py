"""MusicGen-medium [arXiv:2306.05284]: decoder-only transformer over EnCodec
tokens. The EnCodec audio codec (conv frontend) is the allowed STUB — the
pipeline supplies token ids / frame embeddings directly; this is the LM."""
from repro.configs.base import ModalityConfig, ModelConfig, reduced

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio", source="arXiv:2306.05284",
    n_layers=48, d_model=1536, n_heads=24, n_kv=24, d_ff=6144, vocab=2048,
    norm="layernorm", act="gelu",
    modality=ModalityConfig(kind="audio", n_prefix_tokens=0, embed_dim=1536),
)
REDUCED = reduced(CONFIG)
