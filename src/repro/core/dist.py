"""Distribution backends for Dynamic Axial Parallelism (paper §IV.B).

The Evoformer is written once against this interface; three backends give the
three execution modes:

* ``LocalDist``      — single device, all collectives are identity. Oracle.
* ``ShardMapDist``   — *paper-faithful* DAP: runs inside ``shard_map`` over the
  ``model`` mesh axis; ``all_to_all`` swaps the sharded sequence axis exactly
  where Fig. 6 places it, ``all_gather`` materializes cross-axis operands
  (Outer Product Mean, Triangular Updates, pair-bias broadcast).
* ``GspmdDist``      — production path: tensors are global, collectives are
  identity, and ``constrain`` pins the DAP sharding state machine with
  ``with_sharding_constraint`` so GSPMD inserts the *same* collective schedule.
  This is what the multi-pod dry-run lowers and what composes with ZeRO-3 /
  expert parallelism for the assigned architectures.

Sharded-axis convention (shard_map local view): the DAP axis shards exactly one
named dimension of each tensor; helpers below move it.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def named_axis_size(axis: str) -> int:
    """Static size of a named mapped axis, across jax versions: jax>=0.5 has
    jax.lax.axis_size; 0.4.x exposes it via jax.core.axis_frame."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    frame = jax.core.axis_frame(axis)
    return frame if isinstance(frame, int) else frame.size


class LocalDist:
    """Identity backend (1 DAP device)."""

    axis_size: int = 1

    def all_to_all(self, x, *, split_axis: int, concat_axis: int):
        return x

    def all_gather(self, x, *, axis: int):
        return x

    def psum_scatter(self, x, *, axis: int):
        return x

    def constrain(self, x, dims):
        return x


@dataclass(frozen=True)
class ShardMapDist:
    """Explicit-collective DAP; use inside shard_map(..., axis_names=(axis,))."""

    axis: str = "model"

    @property
    def axis_size(self) -> int:
        return named_axis_size(self.axis)

    def all_to_all(self, x, *, split_axis: int, concat_axis: int):
        # Swap which axis is sharded: locally split `split_axis`, concat shards
        # along `concat_axis`. Volume per device: 1/N^2 of the global tensor
        # (paper Table III).
        return jax.lax.all_to_all(
            x, self.axis, split_axis=split_axis, concat_axis=concat_axis,
            tiled=True,
        )

    def all_gather(self, x, *, axis: int):
        return jax.lax.all_gather(x, self.axis, axis=axis, tiled=True)

    def psum_scatter(self, x, *, axis: int):
        return jax.lax.psum_scatter(x, self.axis, scatter_dimension=axis,
                                    tiled=True)

    def constrain(self, x, dims):
        return x


@dataclass(frozen=True)
class GspmdDist:
    """GSPMD backend: sharding constraints instead of explicit collectives.

    ``spec`` arguments name which dim rides the DAP (`model`) axis; batch dims
    ride (`pod`, `data`). The mesh is taken from the surrounding jit context
    (jax.sharding.use_mesh / with mesh:).
    """

    mesh: object  # jax.sharding.Mesh
    axis: str = "model"

    @property
    def axis_size(self) -> int:
        return self.mesh.shape[self.axis]

    def all_to_all(self, x, *, split_axis: int, concat_axis: int):
        return x

    def all_gather(self, x, *, axis: int):
        return x

    def psum_scatter(self, x, *, axis: int):
        return x

    def constrain(self, x, dims):
        """dims: per-axis entries — 'b' (batch axes), 'm' (DAP/model axis) or
        None. Pins the DAP sharding state machine under GSPMD so XLA inserts
        the same all_to_all/all_gather schedule the shard_map path uses."""
        spec = P(*[
            (batch_spec(self.mesh) if d == "b" else
             ("model" if d == "m" else None))
            for d in dims
        ])
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec)
        )


def batch_spec(mesh) -> tuple:
    """Mesh axes that shard the batch dimension: ('pod','data') or ('data',)."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def dap_msa_spec(mesh, shard_dim: str):
    """PartitionSpec for MSA rep (B, s, r, H): shard_dim in {'s','r'}."""
    b = batch_spec(mesh)
    if shard_dim == "s":
        return P(b, "model", None, None)
    return P(b, None, "model", None)


def dap_pair_spec(mesh, shard_dim: str):
    """PartitionSpec for pair rep (B, i, j, H): shard_dim in {'i','j'}."""
    b = batch_spec(mesh)
    if shard_dim == "i":
        return P(b, "model", None, None)
    return P(b, None, "model", None)
