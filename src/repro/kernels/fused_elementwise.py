"""Fused element-wise chains (paper §IV.A.1 "JIT Fusion") as Pallas kernels.

The paper fuses ``bias + sigmoid + element-wise product`` (Evoformer gating)
and ``bias + dropout + add`` (residual path) with TorchScript. Under XLA these
chains usually fuse anyway; the Pallas kernels here make the fusion explicit
and HBM-traffic-optimal for the TPU target, and serve as the unit the paper's
Figure-8/9-style microbenchmarks exercise.

Dropout randomness: the kernel consumes pre-generated uint32 random bits
(threshold compare in-register) rather than an in-kernel PRNG, keeping the
kernel deterministic and identical between interpret (CPU) and TPU modes.

``bias_sigmoid_mul_pallas`` is rank-polymorphic (2D–4D): a grid axis per
leading dim instead of a row-flatten, so mesh-sharded (B, G, ...) leading
dims stay unmerged under GSPMD (a reshape merging two sharded dims would
force an all-gather of the whole representation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.layer_norm import row_grid_specs

ROW_TILE = 8
LANE = 128


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _bias_sigmoid_mul_kernel(g_ref, bg_ref, v_ref, o_ref):
    g = g_ref[...].astype(jnp.float32) + bg_ref[...].astype(jnp.float32)[0]
    o = jax.nn.sigmoid(g) * v_ref[...].astype(jnp.float32)
    o_ref[...] = o.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bias_sigmoid_mul_pallas(
    g: jax.Array, bg: jax.Array, v: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """g, v: (..., R, C) (2D-4D); bg: (C,). sigmoid(g + bg) * v in v.dtype."""
    r, c = g.shape[-2], g.shape[-1]
    c_pad = _pad_to(c, LANE)
    row_tile = ROW_TILE if r >= ROW_TILE else r
    grid, block, ix = row_grid_specs(g.shape, row_tile, c_pad)
    return pl.pallas_call(
        _bias_sigmoid_mul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(block, ix),
            pl.BlockSpec((1, c_pad), lambda *gi: (0, 0)),
            pl.BlockSpec(block, ix),
        ],
        out_specs=pl.BlockSpec(block, ix),
        out_shape=jax.ShapeDtypeStruct(v.shape, v.dtype),
        interpret=interpret,
    )(g, bg.reshape(1, c), v)


def _bias_dropout_add_kernel(x_ref, b_ref, res_ref, keep_ref, o_ref, *, rate: float):
    y = x_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)[0]
    if rate > 0.0:
        y = y * keep_ref[...] / (1.0 - rate)
    o_ref[...] = (res_ref[...].astype(jnp.float32) + y).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rate", "interpret"))
def bias_dropout_add_pallas(
    x: jax.Array,
    b: jax.Array,
    residual: jax.Array,
    keep: jax.Array,
    *,
    rate: float,
    interpret: bool = False,
) -> jax.Array:
    """x, residual: (R, C); keep: (R, C) float32 0/1 mask; b: (C,).
    residual + dropout(x + b, rate)."""
    r, c = x.shape
    c_pad = _pad_to(c, LANE)
    row_tile = ROW_TILE if r >= ROW_TILE else r
    grid = (pl.cdiv(r, row_tile),)
    kernel = functools.partial(_bias_dropout_add_kernel, rate=rate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, c_pad), lambda i: (i, 0)),
            pl.BlockSpec((1, c_pad), lambda i: (0, 0)),
            pl.BlockSpec((row_tile, c_pad), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, c_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, c_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(residual.shape, residual.dtype),
        interpret=interpret,
    )(x, b.reshape(1, c), residual, keep)
