"""Perf-lever correctness: every §Perf optimization must be semantics-
preserving (int8 KV within quantization tolerance)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.decoder import init_model, model_forward


def _decode_consistency(cfg, tol):
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S0, S1 = 2, 16, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S0 + S1), 0,
                              cfg.vocab)
    full = model_forward(params, toks, cfg, mode="train", remat=False,
                         compute_dtype=jnp.float32)["logits"]
    out = model_forward(params, toks[:, :S0], cfg, mode="prefill",
                        max_cache_len=S0 + S1, compute_dtype=jnp.float32)
    cache, lengths = out["cache"], jnp.full((B,), S0, jnp.int32)
    dec = []
    for t in range(S1):
        o = model_forward(params, toks[:, S0 + t:S0 + t + 1], cfg,
                          mode="decode", cache=cache, lengths=lengths,
                          compute_dtype=jnp.float32)
        cache, lengths = o["cache"], lengths + 1
        dec.append(o["logits"])
    dec = jnp.concatenate(dec, 1)
    want = full[:, S0:S0 + S1]
    rel = float(jnp.max(jnp.abs(dec - want))) / (
        float(jnp.max(jnp.abs(want))) + 1e-9)
    assert rel < tol, rel


def test_int8_kv_decode_consistency():
    cfg = dataclasses.replace(get_config("qwen2-1.5b", reduced_variant=True),
                              kv_cache_int8=True)
    _decode_consistency(cfg, tol=0.05)


def test_int8_cache_dtypes():
    cfg = dataclasses.replace(get_config("qwen2-1.5b", reduced_variant=True),
                              kv_cache_int8=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    out = model_forward(params, toks, cfg, mode="prefill", max_cache_len=24)
    c = out["cache"][0]
    assert c["k"].dtype == jnp.int8 and c["v"].dtype == jnp.int8
    assert c["k_s"].dtype == jnp.bfloat16


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "deepseek-v2-236b",
                                  "gemma3-27b"])
def test_attn_q_block_full_is_equivalent(arch):
    """attn_q_block=0 (full-length q) must not change train logits."""
    cfg = get_config(arch, reduced_variant=True)
    cfg_full = dataclasses.replace(cfg, attn_q_block=0)
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    l1 = model_forward(params, toks, cfg, mode="train", remat=False,
                       compute_dtype=jnp.float32)["logits"]
    l2 = model_forward(params, toks, cfg_full, mode="train", remat=False,
                       compute_dtype=jnp.float32)["logits"]
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=3e-5)


def test_bf16_opt_state_converges():
    from repro.optim import adamw_init, adamw_update
    params = {"w": jnp.array([5.0, -3.0])}
    target = jnp.array([1.0, 2.0])
    state = adamw_init(params, state_dtype=jnp.bfloat16)
    assert state.m["w"].dtype == jnp.bfloat16
    for _ in range(400):
        g = {"w": 2 * (params["w"] - target)}
        params, state = adamw_update(params, g, state, 0.05)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=5e-2)


def test_flash_attention_bwd_saves_no_probs():
    """The flash custom-VJP's residuals must be O(S·d), not O(S·kvb·nkv):
    check via the jaxpr that no (.., S, kv_block)-shaped tensor crosses the
    remat/custom-vjp boundary."""
    from repro.layers.attention import blockwise_attention
    B, S, H, hd = 1, 256, 2, 16

    def loss(q, k, v):
        return jnp.sum(
            blockwise_attention(q, k, v, causal=True, kv_block=64) ** 2)

    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    jaxpr = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(q, q, q)
    # residual sizes: anything quadratic (S*S) saved would be 256*256*...;
    # assert the largest intermediate crossing into the bwd is linear in S.
    sizes = [np.prod(v.aval.shape) for eqn in jaxpr.eqns
             for v in eqn.outvars if hasattr(v.aval, "shape")]
    assert max(sizes) <= B * S * H * hd * 4  # no (B,H,S,S)-scale residuals
