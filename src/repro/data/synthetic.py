"""Synthetic-but-faithful data pipelines.

Real AlphaFold preprocessing (jackhmmer/hhblits database search) is CPU-side
and out of scope (cf. ParaFold); we generate features with the *exact shapes,
dtypes and semantics* the model contract requires, deterministically from a
seed, so training/benchmark results are reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class LMBatch:
    tokens: np.ndarray   # (B, S) int32
    targets: np.ndarray  # (B, S) int32 (next-token)
    mask: np.ndarray     # (B, S) float32 loss mask


def lm_batches(
    *, vocab: int, batch: int, seq: int, seed: int = 0
) -> Iterator[LMBatch]:
    """Zipf-distributed token stream with a deterministic generator — matches
    the rank-frequency profile of natural-language corpora closely enough for
    throughput/loss-curve work."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs).astype(np.int32)
        yield LMBatch(
            tokens=toks[:, :-1],
            targets=toks[:, 1:],
            mask=np.ones((batch, seq), np.float32),
        )


N_AA = 21          # 20 amino acids + gap/unknown
N_MSA_TOK = 23     # AlphaFold MSA alphabet (aa + gap + mask)


@dataclass(frozen=True)
class ProteinBatch:
    """AlphaFold featurization contract (the subset the model consumes)."""
    msa: np.ndarray           # (B, N_s, N_r) int32 in [0, N_MSA_TOK)
    msa_mask: np.ndarray      # (B, N_s, N_r) float32
    residue_index: np.ndarray # (B, N_r) int32
    aatype: np.ndarray        # (B, N_r) int32 in [0, N_AA)
    seq_mask: np.ndarray      # (B, N_r) float32
    pseudo_beta: np.ndarray   # (B, N_r, 3) float32 ground-truth CB coords
    bert_mask: np.ndarray     # (B, N_s, N_r) float32: positions masked for the
                              # masked-MSA objective
    true_msa: np.ndarray      # (B, N_s, N_r) int32 unmasked MSA


def protein_batches(
    *, batch: int, n_seq: int, n_res: int, seed: int = 0,
    mask_rate: float = 0.15,
) -> Iterator[ProteinBatch]:
    """Synthetic homologous-family generator: a ground-truth backbone is drawn
    as a self-avoiding-ish random walk; MSA rows are the target sequence with
    position-dependent mutation rates, so co-evolution signal exists for the
    model to learn (loss decreases measurably within a few hundred steps)."""
    rng = np.random.default_rng(seed)
    while True:
        aatype = rng.integers(0, 20, size=(batch, n_res)).astype(np.int32)
        # Backbone: cumulative random unit steps, ~3.8 A spacing like CA traces.
        steps = rng.normal(size=(batch, n_res, 3))
        steps /= np.linalg.norm(steps, axis=-1, keepdims=True) + 1e-8
        coords = np.cumsum(3.8 * steps, axis=1).astype(np.float32)
        # MSA rows: mutate the target with per-position conservation levels.
        conservation = rng.beta(2.0, 2.0, size=(batch, 1, n_res))
        mutate = rng.random((batch, n_seq, n_res)) > conservation
        subs = rng.integers(0, 20, size=(batch, n_seq, n_res))
        msa = np.where(mutate, subs, aatype[:, None, :]).astype(np.int32)
        msa[:, 0] = aatype  # row 0 is the target sequence
        bert_mask = (rng.random((batch, n_seq, n_res)) < mask_rate).astype(np.float32)
        masked_msa = np.where(bert_mask > 0, N_MSA_TOK - 1, msa).astype(np.int32)
        yield ProteinBatch(
            msa=masked_msa,
            msa_mask=np.ones((batch, n_seq, n_res), np.float32),
            residue_index=np.tile(np.arange(n_res, dtype=np.int32), (batch, 1)),
            aatype=aatype,
            seq_mask=np.ones((batch, n_res), np.float32),
            pseudo_beta=coords,
            bert_mask=bert_mask,
            true_msa=msa,
        )
