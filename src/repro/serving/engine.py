"""Batched serving engine: slot-based continuous batching over the decoder's
prefill/decode steps (the inference-side counterpart of the paper's
distributed long-sequence inference — the same model_forward lowers under
DAP/GSPMD shardings for the multi-device path).

Design: a fixed number of slots share one batched KV cache. Requests are
admitted into free slots (B=1 prefill, cache rows scattered into the slot),
all active slots advance together with one batched decode step per token,
finished sequences free their slots immediately.

Memory: the engine's attention blocks come from the AutoChunk planner
(repro.memory.autochunk.plan_decoder_blocks) — the configured
``attn_q_block``/``attn_kv_block`` are kept when the KV cache + prefill
transients fit the HBM budget and shrunk (KV block first) when they don't.
``auto_plan=False`` restores the raw config.

Execution policy: the engine binds one ExecutionPlan (default: the ambient
``current_plan()``), and ``submit(..., plan=...)`` overrides it per request —
e.g. oracle-leg canary requests beside production pallas-leg requests in the
same engine, with no process-global toggles. Each request's prefill runs
under its own plan; decode steps group the active slots by plan and run one
batched decode per distinct plan (each with its own jit wrapper, so plans
never share a trace), committing only that group's cache rows — slots are
independent in a decode step, so discarding the other rows is exact. The
engine's HBM budget for the block planner defaults to the bound plan's
MemoryPolicy.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.exec.plan import ExecutionPlan, current_plan, use_plan
from repro.launch.mesh import HBM_BYTES
from repro.memory.autochunk import plan_decoder_blocks
from repro.models.decoder import init_cache, model_forward


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                     # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0               # 0 => greedy
    eos_id: Optional[int] = None
    # execution plan this request runs under (engine default when None)
    plan: Optional[ExecutionPlan] = None
    # outputs
    generated: list = field(default_factory=list)
    done: bool = False


def sample_token(logits, rng, temperature: float):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(rng, logits / temperature, axis=-1)


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_seq: int = 512, dtype=jnp.bfloat16,
                 auto_plan: bool = True, hbm_budget: int | None = None,
                 plan: ExecutionPlan | None = None):
        self.params = params
        self.plan = plan if plan is not None else current_plan()
        if hbm_budget is None:
            hbm_budget = self.plan.memory.hbm_budget or HBM_BYTES
        if auto_plan:
            cfg, self.block_plan = plan_decoder_blocks(
                cfg, n_slots=n_slots, max_seq=max_seq,
                budget_bytes=hbm_budget)
        else:
            self.block_plan = None
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = init_cache(cfg, n_slots, max_seq, dtype)
        self.lengths = jnp.zeros((n_slots,), jnp.int32)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.pending: list[Request] = []
        self.finished: list[Request] = []
        self._rng = jax.random.PRNGKey(0)
        self._next_uid = 0
        # One jitted decode per distinct ExecutionPlan seen in traffic (the
        # plan steers trace-time branches — wrappers must not be shared).
        self._decode_fns: dict[ExecutionPlan, Callable] = {}

    def _decode_for(self, plan: ExecutionPlan):
        fn = self._decode_fns.get(plan)
        if fn is None:
            def decode(params, toks, cache, lengths):
                with use_plan(plan):
                    return model_forward(params, toks, self.cfg,
                                         mode="decode", cache=cache,
                                         lengths=lengths)

            fn = jax.jit(decode)
            self._decode_fns[plan] = fn
        return fn

    def submit(self, prompt: np.ndarray, *,
               plan: ExecutionPlan | None = None, **kw) -> Request:
        """Queue a request. ``plan`` overrides the engine's bound
        ExecutionPlan for this request only (prefill + its decode group)."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.shape[-1] > self.max_seq:
            # Admitting an over-length prompt would prefill past the cache
            # extent and make every later decode step clamp its .at[].set
            # into the last cache row — silent KV corruption for the whole
            # batch. Reject at the API boundary instead.
            raise ValueError(
                f"prompt length {prompt.shape[-1]} exceeds the engine's "
                f"max_seq={self.max_seq}")
        req = Request(uid=self._next_uid, prompt=prompt,
                      plan=plan if plan is not None else self.plan, **kw)
        self._next_uid += 1
        self.pending.append(req)
        return req

    # --- internals ---

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None or not self.pending:
                continue
            req = self.pending.pop(0)
            prompt = jnp.asarray(req.prompt)[None]            # (1, S)
            with use_plan(req.plan):
                out = model_forward(
                    self.params, prompt, self.cfg, mode="prefill",
                    max_cache_len=self.max_seq)
            # scatter the single-row cache into this slot
            self.cache = jax.tree.map(
                lambda full, one: full.at[:, slot].set(one[:, 0]),
                self.cache, out["cache"])
            self.lengths = self.lengths.at[slot].set(len(req.prompt))
            self.slot_req[slot] = req
            # first generated token comes from the prefill logits
            self._emit(slot, out["logits"][0, -1], req)

    def _release(self, slot: int, req: Request):
        """Finish a request and free its slot (single source of the slot
        teardown invariant)."""
        req.done = True
        self.finished.append(req)
        self.slot_req[slot] = None
        self.lengths = self.lengths.at[slot].set(0)

    def _emit(self, slot: int, logits, req: Request):
        self._rng, sub = jax.random.split(self._rng)
        tok = int(sample_token(logits, sub, req.temperature))
        req.generated.append(tok)
        if (req.eos_id is not None and tok == req.eos_id) or \
                len(req.generated) >= req.max_new_tokens:
            self._release(slot, req)

    def _retire_full(self):
        """Force-finish any slot whose sequence reached max_seq: there is no
        cache row left for another decode write — letting step() run would
        clamp the .at[lengths].set into row max_seq-1 and corrupt the KV
        cache for the remaining tokens."""
        lengths = np.asarray(self.lengths)  # one host read per step, not per slot
        for slot, req in enumerate(self.slot_req):
            if req is not None and int(lengths[slot]) >= self.max_seq:
                self._release(slot, req)

    def step(self):
        """One batched decode step across all active slots — one decode call
        per distinct request plan (slots in a decode step are independent, so
        each plan group commits only its own cache rows and logits)."""
        self._admit()
        self._retire_full()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        toks = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.slot_req[s].generated[-1]
        toks = jnp.asarray(toks)

        groups: dict[ExecutionPlan, list[int]] = {}
        for s in active:
            groups.setdefault(self.slot_req[s].plan, []).append(s)

        new_cache = self.cache
        logits_by_slot: dict[int, jax.Array] = {}
        for plan_, slots in groups.items():
            out = self._decode_for(plan_)(self.params, toks, self.cache,
                                          self.lengths)
            if len(groups) == 1:
                new_cache = out["cache"]
            else:
                idx = jnp.asarray(slots)
                new_cache = jax.tree.map(
                    lambda acc, new: acc.at[:, idx].set(new[:, idx]),
                    new_cache, out["cache"])
            logits = out["logits"][:, 0]
            for s in slots:
                logits_by_slot[s] = logits[s]
        self.cache = new_cache
        self.lengths = self.lengths + jnp.asarray(
            [1 if self.slot_req[s] is not None else 0
             for s in range(self.n_slots)], jnp.int32)
        for s in active:
            req = self.slot_req[s]
            if req is not None:
                self._emit(s, logits_by_slot[s], req)
        return True

    def run(self):
        """Drain all pending + active requests; returns finished Requests."""
        while self.pending or any(r is not None for r in self.slot_req):
            progressed = self.step()
            if not progressed and not self.pending:
                break
        return self.finished
