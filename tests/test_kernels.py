"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
hypothesis property tests, and custom-VJP correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

ATOL = {jnp.float32: 1e-5, jnp.bfloat16: 2.5e-2}


def tols(dt):
    return dict(atol=ATOL[dt], rtol=1e-2)


# ---------------------------------------------------------------------------
# fused softmax
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 1, 8, 128),    # aligned
    (2, 4, 16, 64),    # small lanes
    (3, 2, 5, 130),    # pad both dims
    (2, 8, 33, 256),   # row-tile edge
    (1, 4, 256, 384),  # alphafold-ish row size
])
def test_softmax_sweep(shape, dtype):
    n, h, r, c = shape
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype) * 3
    bias = jax.random.normal(jax.random.PRNGKey(1), (h, r, c), dtype)
    mask = jnp.where(
        jax.random.bernoulli(jax.random.PRNGKey(2), 0.8, (n, c)), 0.0, -1e9
    ).astype(jnp.float32)
    got = ops.fused_softmax(x, bias, mask, scale=0.5)
    want = ref.softmax_ref(x, bias[None], mask, 0.5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tols(dtype))


def test_softmax_bias_batch():
    n, h, r, c = 6, 2, 8, 96
    x = jax.random.normal(jax.random.PRNGKey(0), (n, h, r, c))
    bias = jax.random.normal(jax.random.PRNGKey(1), (3, h, r, c))
    got = ops.fused_softmax(x, bias)
    want = ref.softmax_ref(x, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    r=st.integers(1, 40), c=st.integers(2, 300),
    scale=st.floats(0.1, 4.0),
    seed=st.integers(0, 2**30),
)
def test_softmax_properties(r, c, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, r, c)) * 5
    y = np.asarray(ops.fused_softmax(x, scale=scale))
    # rows sum to 1, all entries in [0, 1]
    np.testing.assert_allclose(y.sum(-1), np.ones((1, 1, r)), atol=1e-5)
    assert (y >= 0).all() and (y <= 1.0 + 1e-6).all()
    # shift invariance
    y2 = np.asarray(ops.fused_softmax(x + 7.0 / scale, scale=scale))
    np.testing.assert_allclose(y, y2, atol=1e-5)


def test_softmax_fully_masked_row_no_nan():
    x = jnp.ones((1, 1, 4, 8))
    mask = jnp.full((1, 8), -1e9, jnp.float32)
    y = ops.fused_softmax(x, mask=mask)
    assert not bool(jnp.isnan(y).any())


def test_softmax_vjp_matches_autodiff():
    n, h, r, c = 4, 2, 8, 96
    x = jax.random.normal(jax.random.PRNGKey(0), (n, h, r, c))
    bias = jax.random.normal(jax.random.PRNGKey(1), (2, h, r, c))
    mask = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(2), 0.9, (n, c)),
                     0.0, -1e9)
    f1 = lambda x, b, m: jnp.sum(jnp.sin(ops.fused_softmax(x, b, m, 0.7)))
    f2 = lambda x, b, m: jnp.sum(jnp.sin(ref.softmax_ref(x, b, m, 0.7)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(x, bias, mask)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(x, bias, mask)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# layer norm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(16, 64), (7, 130), (32, 256), (5, 8960),
                                   (1, 1)])
def test_layernorm_sweep(shape, dtype):
    r, c = shape
    x = jax.random.normal(jax.random.PRNGKey(r + c), shape, dtype) * 2 + 1
    g = jax.random.normal(jax.random.PRNGKey(1), (c,))
    b = jax.random.normal(jax.random.PRNGKey(2), (c,))
    got = ops.layer_norm(x, g, b)
    want = ref.layer_norm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tols(dtype))


@settings(max_examples=25, deadline=None)
@given(r=st.integers(1, 30), c=st.integers(2, 400), seed=st.integers(0, 2**30))
def test_layernorm_properties(r, c, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (r, c)) * 4 + 3
    y = np.asarray(ops.layer_norm(x, jnp.ones((c,)), jnp.zeros((c,))),
                   np.float64)
    np.testing.assert_allclose(y.mean(-1), np.zeros(r), atol=1e-4)
    np.testing.assert_allclose(y.std(-1), np.ones(r), atol=2e-2)


def test_layernorm_vjp_matches_autodiff():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 96))
    g = jax.random.normal(jax.random.PRNGKey(3), (96,))
    b = jax.random.normal(jax.random.PRNGKey(4), (96,))
    f1 = lambda *a: jnp.sum(jnp.cos(ops.layer_norm(*a)))
    f2 = lambda *a: jnp.sum(jnp.cos(ref.layer_norm_ref(*a)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(x, g, b)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(x, g, b)
    for a, bb in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-4)


# ---------------------------------------------------------------------------
# fused element-wise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bias_sigmoid_mul(dtype):
    g = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 96), dtype)
    v = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 96), dtype)
    bg = jax.random.normal(jax.random.PRNGKey(2), (96,))
    got = ops.bias_sigmoid_mul(g, bg, v)
    want = ref.bias_sigmoid_mul_ref(g, bg, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tols(dtype))


@pytest.mark.parametrize("shape", [(16, 64), (3, 7, 130), (2, 5, 9, 96),
                                   (2, 3, 4, 5, 32)])
def test_layernorm_rank_polymorphic(shape):
    """2D-4D inputs run the kernel WITHOUT a row-flatten (grid over leading
    dims — mesh-sharded dims stay unmerged under GSPMD); 5D+ falls back to
    the flattened layout. Values and VJP reductions must be rank-agnostic."""
    c = shape[-1]
    x = jax.random.normal(jax.random.PRNGKey(1), shape) * 2 + 1
    g = jax.random.normal(jax.random.PRNGKey(2), (c,))
    b = jax.random.normal(jax.random.PRNGKey(3), (c,))
    np.testing.assert_allclose(np.asarray(ops.layer_norm(x, g, b)),
                               np.asarray(ref.layer_norm_ref(x, g, b)),
                               atol=1e-5)
    g1 = jax.grad(lambda *a: jnp.sum(jnp.sin(ops.layer_norm(*a))),
                  argnums=(0, 1, 2))(x, g, b)
    g2 = jax.grad(lambda *a: jnp.sum(jnp.sin(ref.layer_norm_ref(*a))),
                  argnums=(0, 1, 2))(x, g, b)
    for a, bb in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-4)


@pytest.mark.parametrize("shape", [(16, 64), (3, 7, 130), (2, 5, 9, 96),
                                   (2, 3, 4, 5, 32)])
def test_bias_sigmoid_mul_rank_polymorphic(shape):
    c = shape[-1]
    g = jax.random.normal(jax.random.PRNGKey(1), shape)
    v = jax.random.normal(jax.random.PRNGKey(2), shape)
    bg = jax.random.normal(jax.random.PRNGKey(3), (c,))
    np.testing.assert_allclose(np.asarray(ops.bias_sigmoid_mul(g, bg, v)),
                               np.asarray(ref.bias_sigmoid_mul_ref(g, bg, v)),
                               atol=1e-6)
    g1 = jax.grad(lambda *a: jnp.sum(jnp.sin(ops.bias_sigmoid_mul(*a))),
                  argnums=(0, 1, 2))(g, bg, v)
    g2 = jax.grad(lambda *a: jnp.sum(jnp.sin(ref.bias_sigmoid_mul_ref(*a))),
                  argnums=(0, 1, 2))(g, bg, v)
    for a, bb in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-5)


def test_bias_dropout_add_deterministic():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 96))
    r = jax.random.normal(jax.random.PRNGKey(1), (4, 96))
    b = jax.random.normal(jax.random.PRNGKey(2), (96,))
    got = ops.bias_dropout_add(x, b, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x + b + r),
                               atol=1e-5)


def test_bias_dropout_add_rate():
    x = jnp.ones((64, 128))
    r = jnp.zeros((64, 128))
    b = jnp.zeros((128,))
    out = np.asarray(ops.bias_dropout_add(x, b, r, rate=0.5,
                                          rng=jax.random.PRNGKey(7)))
    zero_frac = (out == 0).mean()
    assert 0.35 < zero_frac < 0.65
    kept = out[out != 0]
    np.testing.assert_allclose(kept, 2.0, atol=1e-6)  # 1/(1-rate) scaling


def test_kernels_disable_flag():
    from repro.exec.plan import preset, use_plan
    from repro.kernels import ops as ops_mod
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    g = jnp.ones((64,))
    b = jnp.zeros((64,))
    with use_plan(preset("oracle")):
        y_ref = ops_mod.layer_norm(x, g, b)
    y_kern = ops_mod.layer_norm(x, g, b)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_kern),
                               atol=1e-6)
