"""Config registry: one module per assigned architecture (+ AlphaFold)."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    MLAConfig,
    MoEConfig,
    ModalityConfig,
    ModelConfig,
    SSMConfig,
    ShapeConfig,
    reduced,
)

ARCH_MODULES = {
    "qwen2-1.5b": "qwen2_1_5b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "yi-9b": "yi_9b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "musicgen-medium": "musicgen_medium",
    "hymba-1.5b": "hymba_1_5b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "xlstm-125m": "xlstm_125m",
    "gemma3-27b": "gemma3_27b",
    "qwen1.5-32b": "qwen1_5_32b",
}


def list_archs() -> list[str]:
    return list(ARCH_MODULES)


def get_config(arch: str, *, reduced_variant: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.REDUCED if reduced_variant else mod.CONFIG
