"""Runtime observability: contextvar-scoped tracing/metrics, the JSONL
event sink, and the report/aggregation pass.

Scoping follows the repo-wide idiom (``use_plan``, ``inject_faults``):
nothing is global, nothing is ambient. With no tracer scoped, every hook
in this package is a single contextvar read returning a no-op — the
instrumented ServingEngine and train loop are bit-identical to their
uninstrumented selves (asserted in ``tests/test_obs.py``). With one:

    from repro.obs import use_tracer

    with use_tracer() as tr:
        engine.run()
    tr.dump_jsonl("run.jsonl")
    # python -m repro.obs report run.jsonl

Event schema (stable; SCHEMA_VERSION lives in ``events.py``)
-----------------------------------------------------------
One JSON object per line. Common fields on every event:

    seq    emit-order sequence number — the deterministic ordering key.
           Two runs of the same deterministic workload yield the same
           (kind, name, attrs) sequence; only ``*_ns`` durations differ.
    t_ns   monotonic ns since tracer start (never wall clock).
    kind   one of the kinds below.
    name   kind-specific (span name, counter name, request phase, ...).

Kinds and their required fields:

    meta        attrs                    run facts (param_count,
                                         param_bytes, cache_row_bytes,
                                         n_slots, model, ...)
    def         value                    interned payload: ``name`` is a
                                         short label (e.g. "plan:0"),
                                         ``value`` the full serialized
                                         ExecutionPlan — emitted once,
                                         referenced by label thereafter
    span        span_id, parent_id,      nesting tree + interval; jax-
                t_start_ns, dur_ns,      timed leaf spans add
                status, attrs            attrs.dispatch_ns (host return;
                                         compile-dominated on a cold jit
                                         cache) and attrs.block_ns
                                         (block_until_ready = execute)
    counter     delta, value, attrs      cumulative monotonic counter
    gauge       value, attrs             point-in-time (queue_depth,
                                         occupancy, ...)
    request     uid, attrs               serving lifecycle: name is the
                                         phase — queued, rejected,
                                         admitted, prefill, done,
                                         failed, retried, degraded,
                                         quarantined. Exactly one
                                         terminal (done|failed) per
                                         queued uid; ``reconcile``
                                         enforces it
    train_step  step, dur_ns, tokens,    one step: host dispatch time
                metrics                  (no sync), optional tokens/step,
                                         metrics resolved at
                                         serialization time
    jit_entry   key, cache               one call through a plan-keyed
                                         jit site; extra distinct keys
                                         per site bump the
                                         ``trace_cache_miss`` counter
                                         (plan-hash-churn detector)

Adding a span to a new subsystem
--------------------------------
1. ``from repro.obs import trace as obs`` in the subsystem module (the
   alias keeps call sites short and greppable).
2. Wrap host-side phases with ``with obs.span("mysys.phase", key=val):``
   — free when unscoped, nested automatically when inside another span.
3. Time jitted calls with ``obs.timed_call("mysys.kernel", fn, *args)``
   to get the dispatch/execute split; note it adds one
   ``block_until_ready`` sync, so only use it on paths that already sync
   (or that you are explicitly profiling).
4. If the call is jitted on a static policy object, also call
   ``tr.jit_entry("mysys.kernel", label)`` with an interned label from
   ``tr.define("plan", plan.to_dict())`` so cache churn is counted.
5. Counters/gauges: ``obs.count("mysys.things")``,
   ``obs.gauge("mysys.depth", n)``.
6. New event *kinds* (rare) go through ``events.py``: add the kind to
   ``KINDS``, document it here, bump SCHEMA_VERSION if a required field
   changes. Free-form additions belong in ``attrs`` (always backward
   compatible).

``events.py`` and ``report.py`` are pure Python — the schema validator
and aggregator run without jax (CI leg 8 uses them to gate the emitted
stream and BENCH_serving.json).
"""
from repro.obs.events import (KINDS, REQUEST_PHASES, SCHEMA_VERSION,
                              TERMINAL_PHASES, read_jsonl, validate_event,
                              validate_events)
from repro.obs.report import (aggregate, hardware_efficiency, quantiles,
                              reconcile, render_report, validate_bench)
from repro.obs.trace import (Tracer, count, current_tracer, emit, gauge,
                             json_safe, monotonic_ns, span, timed_call,
                             use_tracer)

__all__ = [
    "KINDS", "REQUEST_PHASES", "SCHEMA_VERSION", "TERMINAL_PHASES",
    "Tracer", "aggregate", "count", "current_tracer", "emit", "gauge",
    "hardware_efficiency", "json_safe", "monotonic_ns", "quantiles",
    "read_jsonl", "reconcile", "render_report", "span", "timed_call",
    "use_tracer", "validate_bench", "validate_event", "validate_events",
]
