from repro.parallel import plan  # noqa: F401
