"""End-to-end driver: train an AlphaFold-family model on synthetic protein
batches for a few hundred steps, with checkpointing and eval.

  PYTHONPATH=src python examples/train_alphafold_mini.py \
      --steps 300 --config smoke          # ~3 min on CPU
  PYTHONPATH=src python examples/train_alphafold_mini.py --config mini  # bigger

The loss (masked-MSA + distogram + FAPE) decreases measurably within a few
hundred steps because the synthetic family generator has real co-evolution
signal (data/synthetic.py).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import alphafold as afc
from repro.data import protein_batches
from repro.exec.plan import PRESETS, preset
from repro.exec.session import FastFold
from repro.layers.params import count_params
from repro.train.checkpoint import latest_checkpoint, restore_checkpoint, \
    save_checkpoint
from repro.train.loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="smoke", choices=["smoke", "mini"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--n-res", type=int, default=16)
    ap.add_argument("--n-seq", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/af_mini_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--plan", default="default", choices=sorted(PRESETS),
                    help="ExecutionPlan preset the session binds")
    args = ap.parse_args()

    cfg = afc.SMOKE if args.config == "smoke" else afc.MINI
    # The FastFold facade binds (config, plan) once: the train-loss closure it
    # hands make_train_step carries the kernel/parallel/memory policy.
    ff = FastFold(cfg, preset(args.plan))
    params = ff.init(jax.random.PRNGKey(0))
    print(f"config={args.config} plan={args.plan} "
          f"params={count_params(params):,}")

    init_state, train_step = make_train_step(
        ff.loss_fn, base_lr=args.lr, warmup_steps=20, total_steps=args.steps)
    state = init_state(params)

    ckpt = latest_checkpoint(args.ckpt_dir)
    if ckpt:
        state = restore_checkpoint(ckpt, state)
        print(f"resumed from {ckpt} at step {int(state.step)}")

    gen = protein_batches(batch=args.batch, n_seq=args.n_seq,
                          n_res=args.n_res, seed=0)
    step_fn = jax.jit(train_step)
    t0 = time.time()
    while int(state.step) < args.steps:
        pb = next(gen)
        batch = {k: jnp.asarray(getattr(pb, k)) for k in
                 ("msa", "msa_mask", "residue_index", "aatype", "seq_mask",
                  "pseudo_beta", "bert_mask", "true_msa")}
        state, metrics = step_fn(state, batch,
                                 jax.random.PRNGKey(int(state.step)))
        s = int(state.step)
        if s % 20 == 0 or s == 1:
            dt = (time.time() - t0) / max(1, s)
            print(f"step {s:4d}  loss {float(metrics['loss']):7.4f}  "
                  f"msa {float(metrics['masked_msa']):6.4f}  "
                  f"dist {float(metrics['distogram']):6.4f}  "
                  f"fape {float(metrics['fape']):6.4f}  "
                  f"({dt*1e3:.0f} ms/step)")
        if s % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, s, state)
            print("checkpointed:", path)
    print("done in", round(time.time() - t0, 1), "s")


if __name__ == "__main__":
    main()
