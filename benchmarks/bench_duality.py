"""Paper §IV.C (Fig. 7) — Duality Async Operation / comm-compute overlap.

In XLA the duality pair becomes scheduling freedom (DESIGN.md §2). This bench
compiles the DAP Evoformer and reports, from the scheduled HLO, how many
collectives are async start/done pairs with independent compute inside the
window — the machine-checkable form of the paper's overlap claim. (XLA:CPU
schedules collectives synchronously; the structural placement — swap-back
launched before the pair stack — is still verified via op ordering.)
"""
import os
import re
import subprocess
import sys

from benchmarks.common import csv_row

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import jax, jax.numpy as jnp, re
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack
from repro.core.dap import dap_evoformer_stack, shard_dap_inputs
from repro.core.duality import overlap_report
cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2, head_dim=8,
                      opm_dim=8, tri_mult_dim=16, n_blocks=1)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B,s,r = 1,8,16
msa = jax.random.normal(jax.random.PRNGKey(1),(B,s,r,cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2),(B,r,r,cfg.d_pair))
masks = (jnp.ones((B,s,r)), jnp.ones((B,r)), jnp.ones((B,r,r)))
mesh = jax.make_mesh((1,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
fn = jax.jit(dap_evoformer_stack(mesh, cfg, remat=False))
args = shard_dap_inputs(mesh, msa, pair, *masks)
txt = fn.lower(params, *args).compile().as_text()
rep = overlap_report(txt)
print("OVERLAP", rep)
# structural check: the msa swap-back a2a is emitted before the triangular
# multiplication dots that are independent of it.
lines = txt.splitlines()
a2a_lines = [i for i,l in enumerate(lines) if "all-to-all" in l]
dot_lines = [i for i,l in enumerate(lines) if " dot(" in l]
window = sum(1 for a in a2a_lines if any(a < d for d in dot_lines))
print("PLACEMENT", {"a2a_ops": len(a2a_lines),
                    "a2a_with_compute_after": window})
"""


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        csv_row("duality_overlap", 0, "FAILED " + out.stderr[-200:])
        return
    for ln in out.stdout.strip().splitlines():
        tag, rest = ln.split(" ", 1)
        csv_row(f"duality_{tag.lower()}", 0, rest.replace(",", ";"))


if __name__ == "__main__":
    run()
