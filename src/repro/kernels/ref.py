"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: each kernel test sweeps shapes/dtypes and
asserts allclose against these functions. They are also the fallback path used
by ops.py when a shape is outside the kernel's supported envelope.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_ref(
    x: jax.Array,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
    scale: float = 1.0,
) -> jax.Array:
    """softmax(scale*x + bias + mask) over the last axis, fp32 accumulation.

    x:    (N, H, R, C)
    bias: (B, H, R, C) with N % B == 0 — each bias batch element is shared by
          N/B consecutive rows of x (pair bias in Evoformer: B batch elements,
          N = B*s attention groups). (H, R, C) is accepted as B=1.
    mask: (N, C)     additive, broadcast over H, R
    """
    acc = x.astype(jnp.float32) * scale
    if bias is not None:
        if bias.ndim == 3:
            bias = bias[None]
        b = bias.shape[0]
        n = x.shape[0]
        acc = acc.reshape((b, n // b) + acc.shape[1:])
        acc = acc + bias.astype(jnp.float32)[:, None]
        acc = acc.reshape((n,) + acc.shape[2:])
    if mask is not None:
        acc = acc + mask.astype(jnp.float32)[:, None, None, :]
    out = jax.nn.softmax(acc, axis=-1)
    return out.astype(x.dtype)


def layer_norm_ref(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    eps: float = 1e-5,
) -> jax.Array:
    """LayerNorm over the last axis with affine, fp32 statistics."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return y.astype(x.dtype)


def bias_sigmoid_mul_ref(g: jax.Array, bg: jax.Array, v: jax.Array) -> jax.Array:
    """sigmoid(g + bg) * v — the Evoformer gating fusion (paper §IV.A JIT fusion)."""
    gf = g.astype(jnp.float32) + bg.astype(jnp.float32)
    return (jax.nn.sigmoid(gf) * v.astype(jnp.float32)).astype(v.dtype)


def bias_dropout_add_ref(
    x: jax.Array,
    b: jax.Array,
    residual: jax.Array,
    keep: jax.Array | None,
    rate: float,
) -> jax.Array:
    """residual + dropout(x + b, rate). `keep` is a float 0/1 mask (same shape
    as x); keep=None => no dropout."""
    y = x.astype(jnp.float32) + b.astype(jnp.float32)
    if keep is not None and rate > 0.0:
        y = y * keep.astype(jnp.float32) / (1.0 - rate)
    return (residual.astype(jnp.float32) + y).astype(residual.dtype)
