"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B family card]: dense MHA with QKV bias."""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense", source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=64, d_model=5120, n_heads=40, n_kv=40, d_ff=27392, vocab=152064,
    qkv_bias=True, rope_theta=1e6,
)
REDUCED = reduced(CONFIG)
