"""Distribution backends for Dynamic Axial Parallelism (paper §IV.B).

The Evoformer is written once against this interface; three backends give the
three execution modes:

* ``LocalDist``      — single device, all collectives are identity. Oracle.
* ``ShardMapDist``   — *paper-faithful* DAP: runs inside ``shard_map`` over the
  ``model`` mesh axis; ``all_to_all`` swaps the sharded sequence axis exactly
  where Fig. 6 places it, ``all_gather`` materializes cross-axis operands
  (Outer Product Mean, Triangular Updates, pair-bias broadcast).
* ``GspmdDist``      — production path: tensors are global, collectives are
  identity, and ``constrain`` pins the DAP sharding state machine with
  ``with_sharding_constraint`` so GSPMD inserts the *same* collective schedule.
  This is what the multi-pod dry-run lowers and what composes with ZeRO-3 /
  expert parallelism for the assigned architectures.

Sharded-axis convention (shard_map local view): the DAP axis shards exactly one
named dimension of each tensor; helpers below move it.

``sharded_attention`` contract (the kernel-side sharding hook): group
attention ``softmax(scale*qk^T + bias + mask) @ v`` on the 5D Evoformer
layout — q, k, v ``(B, G, S, H, D)`` with the G (group) dim riding the DAP
axis, bias ``(B, H, S, S)`` replicated over G (or None), mask ``(B, G, S)``
additive fp32 (or None). Each backend must run ``ops.fused_attention`` on
*local* ``(B_loc, G_loc, S, H, D)`` blocks so the kernel's internal
``(B·G, S, H, D)`` flatten never merges two mesh-sharded dims:

* ``LocalDist`` / ``ShardMapDist`` — the tensors in hand are already local
  (whole array / shard_map local view): call the kernel directly.
* ``GspmdDist`` — tensors are global: wrap the kernel call in ``shard_map``
  over ``(batch_axes, 'model')`` with the bias replicated, so each device
  runs the fused kernel on its local block and GSPMD never sees a merged
  ``(B·G, ...)`` reshape (which would force an all-gather of the whole
  representation). ``sharded_attention_supported`` reports whether the
  global shape divides the mesh; callers fall back to the (unflattened)
  scores-materialized path otherwise.

``sharded_triangle`` / ``sharded_opm`` contracts (pair-stack counterparts,
PR 3): the fused triangular-multiplicative-update and outer-product-mean
kernels (``ops.fused_triangle_mult`` / ``ops.fused_outer_product_mean``) on
the DAP layouts — triangle: a_lin/ga ``(B, I, K, C)`` and g_lin
``(B, I, J, D)`` with I (the pair-row dim) riding the DAP axis, b_full
``(B, J, K, C)`` the gathered right operand replicated over it; OPM:
a ``(B, S, I, C)`` with I riding the DAP axis, b_full ``(B, S, J, C)``
replicated. Same rules as attention: LocalDist/ShardMapDist hand the ops
already-local blocks; GspmdDist shard_maps the op over
``(batch_axes, 'model')`` so the kernel's tiling and the backward's j-block
scan run on local shards and no merged-sharded-dim reshape reaches GSPMD.
``sharded_triangle_supported`` / ``sharded_opm_supported`` report whether
the sharded extent divides the mesh; the Evoformer falls back to its
materialized jnp path otherwise.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 promotes shard_map out of experimental
    from jax import shard_map as _shard_map_fn  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map_fn


def shard_map_compat(fn, mesh, in_specs, out_specs):
    """shard_map without replication checking, across jax versions
    (``check_rep`` was renamed ``check_vma``)."""
    try:
        return _shard_map_fn(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)
    except TypeError:
        return _shard_map_fn(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)


def named_axis_size(axis: str) -> int:
    """Static size of a named mapped axis, across jax versions: jax>=0.5 has
    jax.lax.axis_size; 0.4.x exposes it via jax.core.axis_frame."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    frame = jax.core.axis_frame(axis)
    return frame if isinstance(frame, int) else frame.size


def _local_fused_attention(q, k, v, *, bias=None, mask=None, scale=None,
                           kv_tile=0):
    from repro.kernels import ops

    return ops.fused_attention(q, k, v, bias=bias, mask=mask, scale=scale,
                               kv_tile=kv_tile)


def _local_fused_triangle(a_lin, ga, mask, b_full, gamma, beta, w_out, b_out,
                          g_lin, g_bias, *, tile=0):
    from repro.kernels import ops

    return ops.fused_triangle_mult(a_lin, ga, mask, b_full, gamma, beta,
                                   w_out, b_out, g_lin, g_bias, tile=tile)


def _local_fused_opm(a, b_full, mask_a, mask_b, w, bias, *, tile=0):
    from repro.kernels import ops

    return ops.fused_outer_product_mean(a, b_full, mask_a, mask_b, w, bias,
                                        tile=tile)


class LocalDist:
    """Identity backend (1 DAP device)."""

    axis_size: int = 1
    # Tensors handed to this backend are device-local (safe to flatten).
    local_tensors: bool = True

    def all_to_all(self, x, *, split_axis: int, concat_axis: int):
        return x

    def all_gather(self, x, *, axis: int):
        return x

    def psum_scatter(self, x, *, axis: int):
        return x

    def constrain(self, x, dims):
        return x

    def sharded_attention_supported(self, q_shape) -> bool:
        return True

    def sharded_attention(self, q, k, v, *, bias=None, mask=None, scale=None,
                          kv_tile=0):
        return _local_fused_attention(q, k, v, bias=bias, mask=mask,
                                      scale=scale, kv_tile=kv_tile)

    def sharded_triangle_supported(self, i_extent: int) -> bool:
        return True

    def sharded_triangle(self, a_lin, ga, mask, b_full, gamma, beta, w_out,
                         b_out, g_lin, g_bias, *, tile=0):
        return _local_fused_triangle(a_lin, ga, mask, b_full, gamma, beta,
                                     w_out, b_out, g_lin, g_bias, tile=tile)

    def sharded_opm_supported(self, i_extent: int) -> bool:
        return True

    def sharded_opm(self, a, b_full, mask_a, mask_b, w, bias, *, tile=0):
        return _local_fused_opm(a, b_full, mask_a, mask_b, w, bias, tile=tile)


@dataclass(frozen=True)
class ShardMapDist:
    """Explicit-collective DAP; use inside shard_map(..., axis_names=(axis,))."""

    axis: str = "model"
    # Inside shard_map every tensor is a local shard (safe to flatten).
    local_tensors: bool = True

    @property
    def axis_size(self) -> int:
        return named_axis_size(self.axis)

    def all_to_all(self, x, *, split_axis: int, concat_axis: int):
        # Swap which axis is sharded: locally split `split_axis`, concat shards
        # along `concat_axis`. Volume per device: 1/N^2 of the global tensor
        # (paper Table III).
        return jax.lax.all_to_all(
            x, self.axis, split_axis=split_axis, concat_axis=concat_axis,
            tiled=True,
        )

    def all_gather(self, x, *, axis: int):
        return jax.lax.all_gather(x, self.axis, axis=axis, tiled=True)

    def psum_scatter(self, x, *, axis: int):
        return jax.lax.psum_scatter(x, self.axis, scatter_dimension=axis,
                                    tiled=True)

    def constrain(self, x, dims):
        return x

    def sharded_attention_supported(self, q_shape) -> bool:
        return True

    def sharded_attention(self, q, k, v, *, bias=None, mask=None, scale=None,
                          kv_tile=0):
        # Already inside shard_map: q/k/v/mask are the local (B, G/N, S, ...)
        # shards and bias was all_gathered to the full (B, H, S, S) — the
        # fused kernel runs on the local block as-is.
        return _local_fused_attention(q, k, v, bias=bias, mask=mask,
                                      scale=scale, kv_tile=kv_tile)

    def sharded_triangle_supported(self, i_extent: int) -> bool:
        return True

    def sharded_triangle(self, a_lin, ga, mask, b_full, gamma, beta, w_out,
                         b_out, g_lin, g_bias, *, tile=0):
        # Inside shard_map the I dim is already the local shard and b_full
        # was all_gathered to the full (B, J, K, C) — run the op as-is.
        return _local_fused_triangle(a_lin, ga, mask, b_full, gamma, beta,
                                     w_out, b_out, g_lin, g_bias, tile=tile)

    def sharded_opm_supported(self, i_extent: int) -> bool:
        return True

    def sharded_opm(self, a, b_full, mask_a, mask_b, w, bias, *, tile=0):
        return _local_fused_opm(a, b_full, mask_a, mask_b, w, bias, tile=tile)


@dataclass(frozen=True)
class GspmdDist:
    """GSPMD backend: sharding constraints instead of explicit collectives.

    ``spec`` arguments name which dim rides the DAP (`model`) axis; batch dims
    ride (`pod`, `data`). The mesh is taken from the surrounding jit context
    (jax.sharding.use_mesh / with mesh:).
    """

    mesh: object  # jax.sharding.Mesh
    axis: str = "model"
    # Tensors are GLOBAL views whose dims may be mesh-sharded: flattening
    # (B, G, ...) leading dims merges sharded dims (forced all-gather).
    local_tensors: bool = False

    @property
    def axis_size(self) -> int:
        return self.mesh.shape[self.axis]

    def all_to_all(self, x, *, split_axis: int, concat_axis: int):
        return x

    def all_gather(self, x, *, axis: int):
        return x

    def psum_scatter(self, x, *, axis: int):
        return x

    def constrain(self, x, dims):
        """dims: per-axis entries — 'b' (batch axes), 'm' (DAP/model axis) or
        None. Pins the DAP sharding state machine under GSPMD so XLA inserts
        the same all_to_all/all_gather schedule the shard_map path uses."""
        spec = P(*[
            (batch_spec(self.mesh) if d == "b" else
             ("model" if d == "m" else None))
            for d in dims
        ])
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec)
        )

    def _batch_shardable(self, b: int) -> bool:
        bx = batch_spec(self.mesh)
        nb = 1
        for a in bx:
            nb *= self.mesh.shape[a]
        return b % nb == 0

    def sharded_attention_supported(self, q_shape) -> bool:
        """The shard_map wrapper needs the group dim to divide the DAP axis
        (a non-dividing batch dim is handled by replicating batch)."""
        return q_shape[1] % self.mesh.shape[self.axis] == 0

    def sharded_attention(self, q, k, v, *, bias=None, mask=None, scale=None,
                          kv_tile=0):
        """Run the fused kernel under shard_map over (batch_axes, model):
        each device gets its local (B_loc, G_loc, S, H, D) block with the
        gathered bias replicated — the kernel's (B·G) flatten happens on
        local shards only, so GSPMD never inserts a merged-(B, G) all-gather.
        Differentiable (shard_map transposes the kernel's custom_vjp)."""
        bx = batch_spec(self.mesh)
        if not self._batch_shardable(q.shape[0]):
            bx = None  # replicate batch; the DAP axis still shards G
        io = P(bx, self.axis, None, None, None)
        in_specs = [io, io, io]
        args = [q, k, v]
        has_bias, has_mask = bias is not None, mask is not None
        if has_bias:
            in_specs.append(P(bx, None, None, None))
            args.append(bias)
        if has_mask:
            in_specs.append(P(bx, self.axis, None))
            args.append(mask)

        def local_fn(*xs):
            b_ = xs[3] if has_bias else None
            m_ = xs[3 + has_bias] if has_mask else None
            return _local_fused_attention(xs[0], xs[1], xs[2], bias=b_,
                                          mask=m_, scale=scale,
                                          kv_tile=kv_tile)

        return shard_map_compat(local_fn, self.mesh, tuple(in_specs), io)(
            *args)

    def sharded_triangle_supported(self, i_extent: int) -> bool:
        """The shard_map wrapper needs the pair-row (I) dim to divide the
        DAP axis (a non-dividing batch dim is handled by replicating it)."""
        return i_extent % self.mesh.shape[self.axis] == 0

    def sharded_triangle(self, a_lin, ga, mask, b_full, gamma, beta, w_out,
                         b_out, g_lin, g_bias, *, tile=0):
        """Run the fused triangle update under shard_map over
        (batch_axes, model): each device gets its local (B_loc, I_loc, K, C)
        left block and gate tile with the gathered b_full replicated — the
        kernel's tiling and the backward's j-block recompute scan see local
        shards only, so GSPMD never inserts a merged-(B, I) all-gather.
        Differentiable (shard_map transposes the op's custom_vjp)."""
        bx = batch_spec(self.mesh)
        if not self._batch_shardable(a_lin.shape[0]):
            bx = None
        row4 = P(bx, self.axis, None, None)
        rep = lambda x: P(*([None] * x.ndim))
        in_specs = (row4, row4, P(bx, self.axis, None),
                    P(bx, None, None, None), rep(gamma), rep(beta),
                    rep(w_out), rep(b_out), row4, rep(g_bias))

        def local_fn(al, g_, mk, bf, gam, bet, w_, bo, gl, gb):
            return _local_fused_triangle(al, g_, mk, bf, gam, bet, w_, bo,
                                         gl, gb, tile=tile)

        return shard_map_compat(local_fn, self.mesh, in_specs, row4)(
            a_lin, ga, mask, b_full, gamma, beta, w_out, b_out, g_lin,
            g_bias)

    def sharded_opm_supported(self, i_extent: int) -> bool:
        return i_extent % self.mesh.shape[self.axis] == 0

    def sharded_opm(self, a, b_full, mask_a, mask_b, w, bias, *, tile=0):
        """Run the fused outer-product-mean under shard_map over
        (batch_axes, model): the I dim of the left projection/mask rides the
        DAP axis, the gathered right operand and its mask are replicated,
        and the output lands I-sharded — matching the pair rep."""
        bx = batch_spec(self.mesh)
        if not self._batch_shardable(a.shape[0]):
            bx = None
        rep = lambda x: P(*([None] * x.ndim))
        in_specs = (P(bx, None, self.axis, None), P(bx, None, None, None),
                    P(bx, None, self.axis), P(bx, None, None),
                    rep(w), rep(bias))
        out_spec = P(bx, self.axis, None, None)

        def local_fn(a_, bf, ma, mb, w_, bi):
            return _local_fused_opm(a_, bf, ma, mb, w_, bi, tile=tile)

        return shard_map_compat(local_fn, self.mesh, in_specs, out_spec)(
            a, b_full, mask_a, mask_b, w, bias)


def dist_from_policy(policy):
    """Build the dist backend a ``repro.exec.plan.ParallelPolicy`` names —
    the single place the plan's parallel policy turns into one of the three
    backends above (``ParallelPolicy.make_dist`` delegates here). 'gspmd'
    requires ``policy.mesh`` to carry the jax Mesh."""
    if policy.backend == "local":
        return LocalDist()
    if policy.backend == "shard_map":
        return ShardMapDist(axis=policy.axis)
    if policy.backend == "gspmd":
        if policy.mesh is None:
            raise ValueError(
                "ParallelPolicy(backend='gspmd') needs a mesh — e.g. "
                "ParallelPolicy('gspmd', mesh=launch.mesh.make_host_mesh())")
        return GspmdDist(mesh=policy.mesh, axis=policy.axis)
    raise ValueError(f"unknown dist backend {policy.backend!r}")


def batch_spec(mesh) -> tuple:
    """Mesh axes that shard the batch dimension: ('pod','data') or ('data',)."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def dap_msa_spec(mesh, shard_dim: str):
    """PartitionSpec for MSA rep (B, s, r, H): shard_dim in {'s','r'}."""
    b = batch_spec(mesh)
    if shard_dim == "s":
        return P(b, "model", None, None)
    return P(b, None, "model", None)


def dap_pair_spec(mesh, shard_dim: str):
    """PartitionSpec for pair rep (B, i, j, H): shard_dim in {'i','j'}."""
    b = batch_spec(mesh)
    if shard_dim == "i":
        return P(b, "model", None, None)
    return P(b, None, "model", None)
