"""Gemma-3-27B [hf:google/gemma-3-1b-pt family card]: 5:1 local:global
attention (window 1024), qk-norm, head_dim 128, 128k->500k windowed
long-context variant."""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense", source="hf:google/gemma-3-1b-pt",
    n_layers=62, d_model=5376, n_heads=32, n_kv=16, d_ff=21504,
    vocab=262144, head_dim=128, qk_norm=True, rope_theta=1e6,
    sliding_window=1024, subquadratic=True, tie_embeddings=True,
    stages=(("swa", 5), ("attn", 1)) * 10 + (("swa", 2),),
)
REDUCED = reduced(CONFIG, stages=(("swa", 1), ("attn", 1)))
