"""Generic decoder LM covering all assigned architectures.

A model is a sequence of *stages* ``(kind, count)``; layers within a stage are
identical, their params stacked on a leading axis and executed with
``lax.scan`` (small HLO even for 62-layer models — what makes the 512-device
dry-run compile fast). Kind grammar: ``<mixer>[+<ffn>]``:

  mixers: attn (full causal), swa (sliding window), mla (DeepSeek latent),
          mlstm / slstm (xLSTM), hymba / hymba_full (parallel attn+SSM heads)
  ffns:   dense (SwiGLU or GELU per cfg.act), moe, none
  default ffn: dense if d_ff > 0 else none (mlstm/slstm carry their own MLPs).

Three modes share one code path per layer: train (causal, no cache),
prefill (causal + cache out), decode (one token against the cache).
Sliding-window layers keep *rolling* (window-sized) caches, so a 500k-token
gemma3 decode state stores 1024 entries for each local layer.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.attention import (
    AttnDims,
    blockwise_attention,
    decode_attention,
    project_qkv,
    sliding_window_attention,
)
from repro.layers.mlp import gelu_mlp, init_gelu_mlp, init_swiglu, swiglu
from repro.layers.norms import (
    init_layer_norm,
    init_rms_norm,
    layer_norm,
    rms_norm,
)
from repro.layers.params import Params, dense, init_dense, init_embedding, embed
from repro.layers.attention import init_attention
from repro.layers.rotary import apply_rope
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod


def _parse_kind(kind: str, cfg: ModelConfig):
    mixer, _, ffn = kind.partition("+")
    if not ffn:
        ffn = "dense" if cfg.d_ff > 0 else "none"
    return mixer, ffn


def _init_norm(cfg: ModelConfig, d: int):
    return init_rms_norm(d) if cfg.norm == "rmsnorm" else init_layer_norm(d)


def _norm(cfg: ModelConfig, p, x):
    return rms_norm(p, x) if cfg.norm == "rmsnorm" else layer_norm(p, x)


# ---------------------------------------------------------------------------
# layer init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str) -> Params:
    mixer, ffn = _parse_kind(kind, cfg)
    ks = iter(jax.random.split(key, 8))
    d = cfg.d_model
    p: Params = {"ln1": _init_norm(cfg, d)}

    if mixer in ("attn", "swa"):
        p["attn"] = init_attention(
            next(ks), d, cfg.n_heads, cfg.n_kv, cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        )
    elif mixer == "mla":
        p["attn"] = mla_mod.init_mla(next(ks), d, cfg.n_heads, cfg.mla)
    elif mixer == "mlstm":
        p["mlstm"] = xlstm_mod.init_mlstm(next(ks), d, cfg.n_heads)
    elif mixer == "slstm":
        p["slstm"] = xlstm_mod.init_slstm(next(ks), d, cfg.n_heads)
    elif mixer in ("hymba", "hymba_full"):
        p["attn"] = init_attention(
            next(ks), d, cfg.n_heads, cfg.n_kv, cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias,
        )
        p["mamba"] = ssm_mod.init_mamba(next(ks), d, cfg.ssm)
        p["norm_attn"] = init_rms_norm(d)
        p["norm_ssm"] = init_rms_norm(d)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")

    if ffn == "dense":
        p["ln2"] = _init_norm(cfg, d)
        d_ff = cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense) else cfg.d_ff
        if cfg.act == "swiglu":
            p["ffn"] = init_swiglu(next(ks), d, d_ff)
        else:
            p["ffn"] = init_gelu_mlp(next(ks), d, d_ff)
    elif ffn == "moe":
        p["ln2"] = _init_norm(cfg, d)
        p["moe"] = moe_mod.init_moe(next(ks), d, cfg.moe)
    return p


# ---------------------------------------------------------------------------
# layer apply
# ---------------------------------------------------------------------------

def _maybe_gather_kv(k, v, cfg: ModelConfig):
    """DAP KV-gather (paper Fig. 6 style): materialize KV replicated over the
    'model' axis ONCE per layer, so the blockwise scan below never re-gathers.
    No-op outside a mesh context (single-device tests)."""
    if not cfg.gather_kv:
        return k, v
    am = jax.sharding.get_abstract_mesh()
    if am is None or getattr(am, "empty", True):
        return k, v
    from jax.sharding import PartitionSpec as P
    rep = P(*([None] * k.ndim))
    return (jax.lax.with_sharding_constraint(k, rep),
            jax.lax.with_sharding_constraint(v, rep))


def _attn_full(p, x_n, cfg: ModelConfig, positions):
    dims = AttnDims(cfg.n_heads, cfg.n_kv, cfg.resolved_head_dim)
    q, k, v = project_qkv(p, x_n, dims, compute_dtype=x_n.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kg, vg = _maybe_gather_kv(k, v, cfg)
    qb = cfg.attn_q_block or q.shape[1]
    ctx = blockwise_attention(q, kg, vg, causal=True, q_block=qb,
                              kv_block=cfg.attn_kv_block)
    return ctx, (k, v)


def _attn_swa(p, x_n, cfg: ModelConfig, positions):
    dims = AttnDims(cfg.n_heads, cfg.n_kv, cfg.resolved_head_dim)
    q, k, v = project_qkv(p, x_n, dims, compute_dtype=x_n.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    w = cfg.sliding_window
    kg, vg = _maybe_gather_kv(k, v, cfg)
    # SWA keeps small q blocks (its sub-quadratic slicing needs them); 0 keeps
    # the default rather than full-length.
    qb = cfg.attn_q_block or 512
    ctx = sliding_window_attention(q, kg, vg, window=w,
                                   q_block=min(qb, q.shape[1]))
    return ctx, (k, v)


def _out_proj(p, ctx):
    flat = ctx.reshape(ctx.shape[:-2] + (-1,))
    return jnp.einsum("...e,eo->...o", flat, p["wo"]["w"].astype(flat.dtype))


def _swa_cache_from_prefill(k, v, window):
    """Store the last `window` KV rows at their rolling slots."""
    s = k.shape[1]
    w = min(window, s)
    j = jnp.arange(window)
    # slot j holds position p_j = last p < s with p % window == j
    p_j = s - 1 - ((s - 1 - j) % window)
    valid = p_j >= 0
    p_j = jnp.clip(p_j, 0, s - 1)
    kc = jnp.take(k, p_j, axis=1) * valid[None, :, None, None].astype(k.dtype)
    vc = jnp.take(v, p_j, axis=1) * valid[None, :, None, None].astype(v.dtype)
    return kc, vc


def _swa_decode_attn(q, k_cache, v_cache, lengths, window):
    """Rolling-cache decode attention: slot j holds position
    L - ((L - j) mod W) where L = current position."""
    w = k_cache.shape[1]
    j = jnp.arange(w)[None, :]
    L = lengths[:, None]
    p_j = L - ((L - j) % w)
    valid = (p_j >= 0) & (p_j >= L - window + 1) | (j == (L % w))
    # decode_attention masks by `cache_len`; here we inline the same math with
    # the rolling validity mask instead.
    from repro.layers.attention import _expand_kv, NEG_INF
    h = q.shape[2]
    k = _expand_kv(k_cache, h)
    v = _expand_kv(v_cache, h)
    scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    pr = jnp.exp(logits - m)
    out = jnp.einsum("bhqk,bkhd->bhqd", pr.astype(v.dtype), v)
    out = out / jnp.sum(pr, axis=-1)[..., None].astype(out.dtype)
    return out.swapaxes(1, 2).astype(q.dtype)


def _quantize_kv(x):
    """Per-(token, kv-head) symmetric int8: x (B, S, KV, hd) ->
    (int8 values, bf16 scales (B, S, KV, 1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def _scatter_one(cache, new, slots):
    def upd(c, n, l):
        return jax.lax.dynamic_update_slice_in_dim(c, n, l, axis=0)
    return jax.vmap(upd)(cache, new.astype(cache.dtype), slots)


def _scatter_kv(cache_k, cache_v, k_new, v_new, slots):
    return (_scatter_one(cache_k, k_new, slots),
            _scatter_one(cache_v, v_new, slots))


def _pad_cache_seq(tree, max_len: int | None):
    """Pad prefill caches (axis 1 = sequence) out to the decode horizon."""
    if max_len is None:
        return tree

    def pad(x):
        if x.ndim >= 2 and x.shape[1] < max_len:
            cfgpad = [(0, 0)] * x.ndim
            cfgpad[1] = (0, max_len - x.shape[1])
            return jnp.pad(x, cfgpad)
        return x

    return jax.tree.map(pad, tree)


def apply_layer(p: Params, x, cfg: ModelConfig, kind: str, *, mode: str,
                cache=None, lengths=None, pos_offset: int = 0,
                max_cache_len: int | None = None):
    """Returns (x_out, new_cache, aux). x: (B, S, d) or (B, 1, d) for decode."""
    mixer, ffn = _parse_kind(kind, cfg)
    b, s, d = x.shape
    x_n = _norm(cfg, p["ln1"], x)
    positions = (jnp.arange(s) + pos_offset)[None, :] if mode != "decode" \
        else lengths[:, None]
    new_cache = cache
    aux = jnp.zeros((), jnp.float32)

    if mixer in ("attn", "swa"):
        if mode in ("train", "prefill"):
            fn = _attn_full if mixer == "attn" else _attn_swa
            ctx, (k, v) = fn(p["attn"], x_n, cfg, positions)
            if mode == "prefill":
                if mixer == "swa":
                    kc, vc = _swa_cache_from_prefill(k, v, cfg.sliding_window)
                    new_cache = {"k": kc, "v": vc}
                elif cfg.kv_cache_int8:
                    kq, ks = _quantize_kv(k)
                    vq, vs = _quantize_kv(v)
                    new_cache = _pad_cache_seq(
                        {"k": kq, "k_s": ks, "v": vq, "v_s": vs},
                        max_cache_len)
                else:
                    new_cache = _pad_cache_seq({"k": k, "v": v},
                                               max_cache_len)
        else:
            dims = AttnDims(cfg.n_heads, cfg.n_kv, cfg.resolved_head_dim)
            q, k, v = project_qkv(p["attn"], x_n, dims, compute_dtype=x_n.dtype)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            if mixer == "swa":
                w = cfg.sliding_window
                slots = lengths % cache["k"].shape[1]
                ck, cv = _scatter_kv(cache["k"], cache["v"], k, v, slots)
                ctx = _swa_decode_attn(q, ck, cv, lengths, w)
                new_cache = {"k": ck, "v": cv}
            elif cfg.kv_cache_int8:
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                ck = _scatter_one(cache["k"], kq, lengths)
                cks = _scatter_one(cache["k_s"], ks, lengths)
                cv = _scatter_one(cache["v"], vq, lengths)
                cvs = _scatter_one(cache["v_s"], vs, lengths)
                ctx = decode_attention(q, _dequantize_kv(ck, cks),
                                       _dequantize_kv(cv, cvs), lengths + 1)
                new_cache = {"k": ck, "k_s": cks, "v": cv, "v_s": cvs}
            else:
                ck, cv = _scatter_kv(cache["k"], cache["v"], k, v, lengths)
                ctx = decode_attention(q, ck, cv, lengths + 1)
                new_cache = {"k": ck, "v": cv}
        y = _out_proj(p["attn"], ctx)
        x = x + y

    elif mixer == "mla":
        if mode in ("train", "prefill"):
            y, kv = mla_mod.mla_attention_train(
                p["attn"], x_n, cfg.n_heads, cfg.mla, positions=positions,
                theta=cfg.rope_theta, q_block=cfg.attn_q_block,
                kv_block=cfg.attn_kv_block,
                gather_kv_fn=(lambda kk, vv: _maybe_gather_kv(kk, vv, cfg))
                if cfg.gather_kv else None)
            if mode == "prefill":
                new_cache = _pad_cache_seq(kv, max_cache_len)
        else:
            y, new_cache = mla_mod.mla_attention_decode(
                p["attn"], x_n, cache, lengths, cfg.n_heads, cfg.mla,
                theta=cfg.rope_theta)
        x = x + y

    elif mixer == "mlstm":
        if mode in ("train", "prefill"):
            y, st = xlstm_mod.mlstm_forward(p["mlstm"], x_n, cfg.n_heads)
            new_cache = st if mode == "prefill" else None
        else:
            y, new_cache = xlstm_mod.mlstm_decode(p["mlstm"], x_n, cache,
                                                  cfg.n_heads)
        x = x + y

    elif mixer == "slstm":
        if mode in ("train", "prefill"):
            y, st = xlstm_mod.slstm_forward(p["slstm"], x_n)
            new_cache = st if mode == "prefill" else None
        else:
            y, new_cache = xlstm_mod.slstm_decode(p["slstm"], x_n, cache)
        x = x + y

    elif mixer in ("hymba", "hymba_full"):
        window = cfg.sliding_window if mixer == "hymba" else 0
        if mode in ("train", "prefill"):
            dims = AttnDims(cfg.n_heads, cfg.n_kv, cfg.resolved_head_dim)
            q, k, v = project_qkv(p["attn"], x_n, dims, compute_dtype=x_n.dtype)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            kg, vg = _maybe_gather_kv(k, v, cfg)
            qb = cfg.attn_q_block or s
            if window:
                ctx = sliding_window_attention(
                    q, kg, vg, window=window,
                    q_block=min(cfg.attn_q_block or 512, s))
            else:
                ctx = blockwise_attention(q, kg, vg, causal=True, q_block=qb,
                                          kv_block=cfg.attn_kv_block)
            attn_y = _out_proj(p["attn"], ctx)
            ssm_y, ssm_st = ssm_mod.mamba_forward(p["mamba"], x_n, cfg.ssm)
            if mode == "prefill":
                if window:
                    kc, vc = _swa_cache_from_prefill(k, v, window)
                else:
                    padded = _pad_cache_seq({"k": k, "v": v}, max_cache_len)
                    kc, vc = padded["k"], padded["v"]
                new_cache = {"attn": {"k": kc, "v": vc}, "ssm": ssm_st}
        else:
            dims = AttnDims(cfg.n_heads, cfg.n_kv, cfg.resolved_head_dim)
            q, k, v = project_qkv(p["attn"], x_n, dims, compute_dtype=x_n.dtype)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            ca = cache["attn"]
            if window:
                slots = lengths % ca["k"].shape[1]
                ck, cv = _scatter_kv(ca["k"], ca["v"], k, v, slots)
                ctx = _swa_decode_attn(q, ck, cv, lengths, window)
            else:
                ck, cv = _scatter_kv(ca["k"], ca["v"], k, v, lengths)
                ctx = decode_attention(q, ck, cv, lengths + 1)
            attn_y = _out_proj(p["attn"], ctx)
            ssm_y, ssm_st = ssm_mod.mamba_decode(p["mamba"], x_n, cache["ssm"],
                                                 cfg.ssm)
            new_cache = {"attn": {"k": ck, "v": cv}, "ssm": ssm_st}
        # Hymba fusion: mean of per-branch normalized outputs.
        y = 0.5 * (rms_norm(p["norm_attn"], attn_y) +
                   rms_norm(p["norm_ssm"], ssm_y))
        x = x + y

    # --- FFN ---
    if ffn == "dense":
        h = _norm(cfg, p["ln2"], x)
        h = swiglu(p["ffn"], h) if cfg.act == "swiglu" else gelu_mlp(p["ffn"], h)
        x = x + h
    elif ffn == "moe":
        h = _norm(cfg, p["ln2"], x)
        h, aux = moe_mod.moe_ffn(p["moe"], h, cfg.moe)
        x = x + h
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# model: init / cache / forward / loss
# ---------------------------------------------------------------------------

def init_model(key, cfg: ModelConfig) -> Params:
    ks = iter(jax.random.split(key, 4 + len(cfg.resolved_stages)))
    params: Params = {
        "embed": init_embedding(next(ks), cfg.vocab, cfg.d_model),
        "final_norm": _init_norm(cfg, cfg.d_model),
        "stages": [],
    }
    for kind, count in cfg.resolved_stages:
        layer_keys = jax.random.split(next(ks), count)
        params["stages"].append(
            jax.vmap(lambda k, kind=kind: init_layer(k, cfg, kind))(layer_keys)
        )
    if not cfg.tie_embeddings:
        params["head"] = init_dense(next(ks), cfg.d_model, cfg.vocab,
                                    bias=False)
    return params


def _layer_cache_shape(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                       dtype):
    mixer, _ = _parse_kind(kind, cfg)
    kv, hd = cfg.n_kv, cfg.resolved_head_dim
    d_in = cfg.ssm.expand * cfg.d_model if cfg.ssm else 0

    def kv_cache(seq):
        return {"k": jnp.zeros((batch, seq, kv, hd), dtype),
                "v": jnp.zeros((batch, seq, kv, hd), dtype)}

    def kv_cache_int8(seq):
        return {"k": jnp.zeros((batch, seq, kv, hd), jnp.int8),
                "k_s": jnp.zeros((batch, seq, kv, 1), jnp.bfloat16),
                "v": jnp.zeros((batch, seq, kv, hd), jnp.int8),
                "v_s": jnp.zeros((batch, seq, kv, 1), jnp.bfloat16)}

    if mixer == "attn":
        return kv_cache_int8(max_seq) if cfg.kv_cache_int8 \
            else kv_cache(max_seq)
    if mixer == "swa":
        return kv_cache(min(cfg.sliding_window, max_seq))
    if mixer == "mla":
        return mla_mod.init_mla_cache(batch, max_seq, cfg.mla, dtype)
    if mixer == "mlstm":
        return xlstm_mod.init_mlstm_state(batch, 2 * cfg.d_model, cfg.n_heads)
    if mixer == "slstm":
        return xlstm_mod.init_slstm_state(batch, cfg.d_model)
    if mixer == "hymba":
        return {"attn": kv_cache(min(cfg.sliding_window, max_seq)),
                "ssm": ssm_mod.init_mamba_state(batch, d_in, cfg.ssm)}
    if mixer == "hymba_full":
        return {"attn": kv_cache(max_seq),
                "ssm": ssm_mod.init_mamba_state(batch, d_in, cfg.ssm)}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16):
    """Stacked per-stage caches: stage i -> pytree with leading `count` axis."""
    caches = []
    for kind, count in cfg.resolved_stages:
        one = _layer_cache_shape(cfg, kind, batch, max_seq, dtype)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (count,) + x.shape), one))
    return caches


def model_forward(params: Params, tokens, cfg: ModelConfig, *,
                  mode: str = "train", cache=None, lengths=None,
                  prefix_embeds=None, remat: bool = True,
                  max_cache_len: int | None = None,
                  shard_x=None,
                  compute_dtype=jnp.bfloat16):
    """tokens: (B, S_text) int32 (S_text=1 for decode). prefix_embeds:
    (B, P, d) for VLM/audio stubs (train/prefill only). `shard_x` is an
    optional residual-stream constrainer (with_sharding_constraint under the
    production mesh: the DAP sequence sharding is pinned after every layer).
    Returns dict with logits, cache (prefill/decode), aux (MoE loss)."""
    shard_x = shard_x or (lambda x: x)
    x = embed(params["embed"], tokens, compute_dtype)
    if cfg.modality and prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(compute_dtype), x], axis=1)
    if cfg.family == "dense" and cfg.norm == "rmsnorm" and cfg.qk_norm:
        # gemma convention: scale embeddings by sqrt(d_model)
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    x = shard_x(x)

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    pos_offset = 0
    for si, (kind, count) in enumerate(cfg.resolved_stages):
        p_stage = params["stages"][si]
        if mode == "train":
            def body(xc, p, kind=kind):
                y, _, aux = apply_layer(p, xc, cfg, kind, mode="train")
                return shard_x(y), aux
            if remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable,
                    static_argnums=())
            x, auxs = jax.lax.scan(body, x, p_stage)
            aux_total = aux_total + jnp.sum(auxs)
        elif mode == "prefill":
            def body(xc, p, kind=kind):
                y, c, aux = apply_layer(p, xc, cfg, kind, mode="prefill",
                                        max_cache_len=max_cache_len)
                return shard_x(y), (c, aux)
            x, (stage_cache, auxs) = jax.lax.scan(body, x, p_stage)
            new_caches.append(stage_cache)
            aux_total = aux_total + jnp.sum(auxs)
        else:  # decode
            def body(xc, pc, kind=kind):
                p, c = pc
                y, c2, _ = apply_layer(p, xc, cfg, kind, mode="decode",
                                       cache=c, lengths=lengths)
                return y, c2
            x, stage_cache = jax.lax.scan(body, x, (p_stage, cache[si]))
            new_caches.append(stage_cache)

    x = _norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"]["table"].astype(x.dtype))
    else:
        logits = dense(params["head"], x)
    return {
        "logits": logits,
        "cache": new_caches if mode != "train" else None,
        "aux": aux_total,
    }


def lm_loss(params: Params, batch: dict, cfg: ModelConfig, shard_x=None):
    """batch: tokens (B,S), targets (B,S), mask (B,S), optional prefix_embeds.
    Loss is computed on text positions only (prefix positions are dropped)."""
    out = model_forward(params, batch["tokens"], cfg, mode="train",
                        prefix_embeds=batch.get("prefix_embeds"),
                        shard_x=shard_x)
    logits = out["logits"]
    n_text = batch["tokens"].shape[1]
    logits = logits[:, -n_text:]  # drop prefix positions (VLM/audio)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, batch["targets"][..., None], axis=-1)[..., 0]
    mask = batch["mask"]
    ce = -jnp.sum(ll * mask) / (jnp.sum(mask) + 1e-6)
    loss = ce + out["aux"]
    return loss, {"loss": loss, "ce": ce, "aux": out["aux"],
                  "ppl": jnp.exp(jnp.minimum(ce, 20.0))}

