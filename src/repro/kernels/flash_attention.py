"""Fused flash-style gated-attention Pallas TPU kernels (forward + backward).

Forward: ``out = softmax(scale * q @ k^T + bias + mask) @ v`` with an online
softmax over KV tiles: the scores tile lives only in VMEM, so the
``(N, H, R, R)`` scores tensor the paper's §III.B identifies as the cubic
``N_r^3 * H`` memory transient never reaches HBM. HBM traffic per q tile is
linear in the KV tile size instead of quadratic in sequence length — the
fused-attention gap ScaleFold (arXiv 2404.11068) closes on top of FastFold's
kernel set.

Backward (``flash_attention_bwd_pallas``): recompute-style flash backward
from the saved ``(q, k, v, out->delta, lse)`` residuals — the probs/ds tiles
are rebuilt per (q_tile, kv_tile) cell in VMEM, so the fp32
``(N, H, Sq, kv_block)`` recompute transient the jnp KV-scan backward streams
through HBM never materializes. Three sweeps (dq; dk/dv + the mask
reduction; the bias reduction), each a separate grid ordered so its
accumulator lives in VMEM scratch across the innermost dimension — except
when the bias group is mesh-local (rep == 1, the shard-mapped DAP layout):
then the dq sweep's recomputed ds tiles ARE dbias, so they are emitted as a
second output of sweep 1 and the bias-reduction sweep is skipped (two sweeps
total, one fewer full recompute pass over the tiles).

An XLA-native forward with identical semantics (``flash_attention_xla``,
lax.scan over KV tiles) serves as the non-TPU leg: interpret-mode Pallas is a
per-grid-cell loop, ~2x the jnp online-softmax path on CPU smoke shapes.

Kernel contract (enforced/prepared by ops.fused_attention):

  q, k, v : (N, H, S, D) with D already zero-padded to a 128-lane multiple
            and S padded to the q/kv tile (zero rows — harmless: they attend
            over the real KV range and are sliced off by the caller).
  bias    : (B, H, Sq, Skv) additive, ``N % B == 0`` (each bias batch element
            is shared by N/B consecutive rows of q — the Evoformer pair bias
            shared across the MSA/group axis), or None.
  mask    : (N, Skv) additive fp32 (0 / NEG_INF-style), or None. Mask values
            must be finite (use ~-1e9, not -inf).
  kv_len  : true KV length before padding; padded columns are masked to
            ``NEG_INF`` in-kernel so they never win the max nor add to the sum.

Returns ``out (N, H, Sq, D)`` in the input dtype and the fp32 log-sum-exp
``lse (N, H, Sq)`` that the recompute backward in ops.py needs.

Grid: ``(N, H, Sq/q_tile, Skv/kv_tile)`` with KV innermost. The fp32 running
(m, l, acc) state lives in VMEM scratch across the KV sweep; the output block
is written once on the final KV step (Pallas revisiting semantics keep the
block resident until its index changes). fp32 statistics, MXU GEMMs with
fp32 accumulation (``preferred_element_type``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
NEG_INF = -1e30  # finite: keeps exp(s - m) NaN-free even for all-masked rows


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _flash_kernel(*refs, scale: float, kv_len: int, kv_tile: int,
                  has_bias: bool, has_mask: bool):
    idx = 0
    q_ref = refs[idx]; idx += 1
    k_ref = refs[idx]; idx += 1
    v_ref = refs[idx]; idx += 1
    b_ref = refs[idx] if has_bias else None
    idx += int(has_bias)
    mk_ref = refs[idx] if has_mask else None
    idx += int(has_mask)
    o_ref, lse_ref = refs[idx], refs[idx + 1]
    acc_ref, m_ref, l_ref = refs[idx + 2], refs[idx + 3], refs[idx + 4]

    jk = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                   # (q_tile, d_pad)
    k = k_ref[0, 0]                                   # (kv_tile, d_pad)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                         # (q_tile, kv_tile)
    if b_ref is not None:
        s = s + b_ref[0, 0].astype(jnp.float32)
    if mk_ref is not None:
        s = s + mk_ref[0].astype(jnp.float32)[None, :]
    # Neutralize KV padding: padded columns must not win the max nor
    # contribute to the sum.
    col = jk * kv_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < kv_len, s, NEG_INF)

    m_prev = m_ref[:, :1]                             # (q_tile, 1)
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jk == n_kv - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0, :] = (m_ref[:, :1] + jnp.log(l))[:, 0]


@functools.partial(
    jax.jit,
    static_argnames=("scale", "kv_len", "q_tile", "kv_tile", "has_bias",
                     "has_mask", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
    *,
    scale: float,
    kv_len: int,
    q_tile: int,
    kv_tile: int,
    has_bias: bool = False,
    has_mask: bool = False,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Pre-padded inputs only — see module docstring; use ops.fused_attention."""
    n, h, sq, d = q.shape
    skv = k.shape[2]
    assert sq % q_tile == 0 and skv % kv_tile == 0 and d % LANE == 0, \
        (q.shape, k.shape, q_tile, kv_tile)
    grid = (n, h, sq // q_tile, skv // kv_tile)

    in_specs = [
        pl.BlockSpec((1, 1, q_tile, d), lambda i, j, iq, jk: (i, j, iq, 0)),
        pl.BlockSpec((1, 1, kv_tile, d), lambda i, j, iq, jk: (i, j, jk, 0)),
        pl.BlockSpec((1, 1, kv_tile, d), lambda i, j, iq, jk: (i, j, jk, 0)),
    ]
    operands = [q, k, v]
    if has_bias:
        assert bias is not None and bias.ndim == 4 and n % bias.shape[0] == 0
        rep = n // bias.shape[0]
        in_specs.append(
            pl.BlockSpec((1, 1, q_tile, kv_tile),
                         lambda i, j, iq, jk: (i // rep, j, iq, jk))
        )
        operands.append(bias)
    if has_mask:
        assert mask is not None and mask.shape == (n, skv)
        in_specs.append(
            pl.BlockSpec((1, kv_tile), lambda i, j, iq, jk: (i, jk))
        )
        operands.append(mask)

    kernel = functools.partial(
        _flash_kernel, scale=scale, kv_len=kv_len, kv_tile=kv_tile,
        has_bias=has_bias, has_mask=has_mask,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, q_tile, d), lambda i, j, iq, jk: (i, j, iq, 0)),
            pl.BlockSpec((1, 1, q_tile), lambda i, j, iq, jk: (i, j, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((n, h, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_tile, d), jnp.float32),      # acc
            pltpu.VMEM((q_tile, LANE), jnp.float32),   # running max m
            pltpu.VMEM((q_tile, LANE), jnp.float32),   # running sum l
        ],
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# XLA-native forward (non-TPU leg). Same math, same residuals.
# ---------------------------------------------------------------------------


def stage_kv_blocks(k, v, bias, mask, kv_tile: int) -> dict:
    """Shared KV-tile staging for the lax.scan legs (XLA-native forward and
    the jnp recompute backward in ops._attn_bwd): pad Skv to a kv_tile
    multiple and reshape into per-tile scan blocks. Padded columns carry a
    NEG_INF additive mask so recomputed probs are exactly zero there.

    k, v (N, Skv, H, D); bias (B, H, Sq, Skv) or None; mask (N, Skv) fp32 or
    None. Returns xs with leading dim nkv: 'k'/'v' (nkv, N, kvb, H, D),
    'b' (nkv, B, H, Sq, kvb) if bias, 'm' (nkv, N, kvb) if mask or padding.
    """
    n, skv, h, d = k.shape
    nkv = -(-skv // kv_tile)
    skv_pad = nkv * kv_tile
    kp = jnp.pad(k, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
    mcomb = None
    if mask is not None:
        mcomb = jnp.pad(mask.astype(jnp.float32),
                        ((0, 0), (0, skv_pad - skv)),
                        constant_values=NEG_INF)
    elif skv_pad != skv:
        col = jnp.arange(skv_pad)
        mcomb = jnp.broadcast_to(
            jnp.where(col < skv, 0.0, NEG_INF)[None, :], (n, skv_pad))
    xs = {
        "k": kp.reshape(n, nkv, kv_tile, h, d).swapaxes(0, 1),
        "v": vp.reshape(n, nkv, kv_tile, h, v.shape[-1]).swapaxes(0, 1),
    }
    if bias is not None:
        nb, _, sq, _ = bias.shape
        bp = jnp.pad(bias, ((0, 0), (0, 0), (0, 0), (0, skv_pad - skv)))
        xs["b"] = bp.reshape(nb, h, sq, nkv, kv_tile).transpose(3, 0, 1, 2, 4)
    if mcomb is not None:
        xs["m"] = mcomb.reshape(n, nkv, kv_tile).swapaxes(0, 1)
    return xs


def apply_block_bias_mask(s, blk, n: int):
    """Add a staged bias/mask block to a scores block s (N, H, Sq, kvb): the
    bias is shared by N/B consecutive rows (Evoformer bias-group contract)."""
    if "b" in blk:
        nb = blk["b"].shape[0]
        s = s.reshape((nb, n // nb) + s.shape[1:])
        s = s + blk["b"].astype(jnp.float32)[:, None]
        s = s.reshape((n,) + s.shape[2:])
    if "m" in blk:
        s = s + blk["m"][:, None, None, :]
    return s


def flash_attention_xla(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
    *,
    scale: float,
    kv_tile: int,
) -> tuple[jax.Array, jax.Array]:
    """Online-softmax attention as a lax.scan over KV tiles — no Pallas.

    Layout matches ops.fused_attention (NOT the kernel): q (N, Sq, H, D);
    k, v (N, Skv, H, D); bias (B, H, Sq, Skv) with N % B == 0; mask (N, Skv)
    additive fp32. Returns (out (N, Sq, H, D) in q.dtype, lse (N, H, Sq) fp32)
    — the same residual contract as the Pallas kernel, so the recompute
    backward is shared. Used when ``jax.default_backend() != "tpu"``: the
    memory behavior (peak transient = one fp32 (N, H, Sq, kv_tile) block) is
    the same; XLA owns the fusion instead of Mosaic.
    """
    n, sq, h, d = q.shape
    skv = k.shape[1]
    kvb = min(kv_tile, skv)
    xs = stage_kv_blocks(k, v, bias, mask, kvb)

    def kv_step(carry, blk):
        m, l, acc = carry
        s = jnp.einsum("nqhd,nkhd->nhqk", q, blk["k"],
                       preferred_element_type=jnp.float32) * scale
        s = apply_block_bias_mask(s, blk, n)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "nhqk,nkhd->nhqd", p, blk["v"].astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((n, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((n, h, sq), jnp.float32)
    a0 = jnp.zeros((n, h, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), xs)
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).swapaxes(1, 2).astype(q.dtype)
    lse = m + jnp.log(l)
    return out, lse


# ---------------------------------------------------------------------------
# Fused backward kernels
# ---------------------------------------------------------------------------
#
# ds recompute shared by all three sweeps: rebuild the scores tile from
# (q, k, bias, mask), the probs tile from lse, and d(logits) from
# (do, v, delta) — all in VMEM, fp32.


def _recompute_ds(q, k, v, do, lse, delta, b_blk, m_blk, *, scale, kv_len,
                  kv_tile, jk):
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                          # (q_tile, kv_tile)
    if b_blk is not None:
        s = s + b_blk.astype(jnp.float32)
    if m_blk is not None:
        s = s + m_blk.astype(jnp.float32)[None, :]
    col = jk * kv_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < kv_len, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                      # (q_tile, kv_tile)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (q_tile, kv_tile)
    ds = p * (dp - delta[:, None])
    return p, ds


def _bwd_dq_kernel(*refs, scale, kv_len, kv_tile, has_bias, has_mask,
                   emit_dbias=False):
    idx = 0
    q_ref = refs[idx]; idx += 1
    k_ref = refs[idx]; idx += 1
    v_ref = refs[idx]; idx += 1
    do_ref = refs[idx]; idx += 1
    lse_ref = refs[idx]; idx += 1
    dl_ref = refs[idx]; idx += 1
    b_ref = refs[idx] if has_bias else None
    idx += int(has_bias)
    mk_ref = refs[idx] if has_mask else None
    idx += int(has_mask)
    dq_ref = refs[idx]; idx += 1
    db_ref = refs[idx] if emit_dbias else None
    idx += int(emit_dbias)
    dq_acc = refs[idx]

    jk = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    _, ds = _recompute_ds(
        q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], do_ref[0, 0],
        lse_ref[0, 0], dl_ref[0, 0],
        b_ref[0, 0] if b_ref is not None else None,
        mk_ref[0] if mk_ref is not None else None,
        scale=scale, kv_len=kv_len, kv_tile=kv_tile, jk=jk)
    dq_acc[...] += jax.lax.dot_general(
        ds, k_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale
    if db_ref is not None:
        # Mesh-local bias group (rep == 1): dbias IS the ds tile — each
        # (iq, jk) grid cell owns its output block, so the separate
        # bias-reduction sweep collapses into this one.
        db_ref[0, 0] = ds

    @pl.when(jk == n_kv - 1)
    def _epilogue():
        dq_ref[0, 0] = dq_acc[...]


def _bwd_dkv_kernel(*refs, scale, kv_len, kv_tile, has_bias, has_mask):
    idx = 0
    q_ref = refs[idx]; idx += 1
    k_ref = refs[idx]; idx += 1
    v_ref = refs[idx]; idx += 1
    do_ref = refs[idx]; idx += 1
    lse_ref = refs[idx]; idx += 1
    dl_ref = refs[idx]; idx += 1
    b_ref = refs[idx] if has_bias else None
    idx += int(has_bias)
    mk_ref = refs[idx] if has_mask else None
    idx += int(has_mask)
    dk_ref, dv_ref = refs[idx], refs[idx + 1]
    idx += 2
    dm_ref = refs[idx] if has_mask else None
    idx += int(has_mask)
    dk_acc, dv_acc = refs[idx], refs[idx + 1]
    dm_acc = refs[idx + 2] if has_mask else None

    iq = pl.program_id(3)
    n_q = pl.num_programs(3)
    jk = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)
        if dm_acc is not None:
            dm_acc[...] = jnp.zeros_like(dm_acc)

    p, ds = _recompute_ds(
        q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], do_ref[0, 0],
        lse_ref[0, 0], dl_ref[0, 0],
        b_ref[0, 0] if b_ref is not None else None,
        mk_ref[0] if mk_ref is not None else None,
        scale=scale, kv_len=kv_len, kv_tile=kv_tile, jk=jk)
    dv_acc[...] += jax.lax.dot_general(
        p, do_ref[0, 0].astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (kv_tile, d)
    dk_acc[...] += jax.lax.dot_general(
        ds, q_ref[0, 0].astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale
    if dm_acc is not None:
        dm_acc[...] += jnp.broadcast_to(
            jnp.sum(ds, axis=0, keepdims=True), dm_acc.shape)

    @pl.when(iq == n_q - 1)
    def _epilogue():
        dk_ref[0, 0] = dk_acc[...]
        dv_ref[0, 0] = dv_acc[...]
        if dm_ref is not None:
            dm_ref[0, 0, :] = dm_acc[0, :]


def _bwd_dbias_kernel(*refs, scale, kv_len, kv_tile, has_mask):
    idx = 0
    q_ref = refs[idx]; idx += 1
    k_ref = refs[idx]; idx += 1
    v_ref = refs[idx]; idx += 1
    do_ref = refs[idx]; idx += 1
    lse_ref = refs[idx]; idx += 1
    dl_ref = refs[idx]; idx += 1
    b_ref = refs[idx]; idx += 1
    mk_ref = refs[idx] if has_mask else None
    idx += int(has_mask)
    db_ref, db_acc = refs[idx], refs[idx + 1]

    r = pl.program_id(4)
    rep = pl.num_programs(4)
    jk = pl.program_id(3)

    @pl.when(r == 0)
    def _init():
        db_acc[...] = jnp.zeros_like(db_acc)

    _, ds = _recompute_ds(
        q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], do_ref[0, 0],
        lse_ref[0, 0], dl_ref[0, 0], b_ref[0, 0],
        mk_ref[0] if mk_ref is not None else None,
        scale=scale, kv_len=kv_len, kv_tile=kv_tile, jk=jk)
    db_acc[...] += ds

    @pl.when(r == rep - 1)
    def _epilogue():
        db_ref[0, 0] = db_acc[...]


@functools.partial(
    jax.jit,
    static_argnames=("scale", "kv_len", "q_tile", "kv_tile", "has_bias",
                     "has_mask", "interpret"),
)
def flash_attention_bwd_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    do: jax.Array,
    lse: jax.Array,
    delta: jax.Array,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
    *,
    scale: float,
    kv_len: int,
    q_tile: int,
    kv_tile: int,
    has_bias: bool = False,
    has_mask: bool = False,
    interpret: bool = False,
):
    """Fused flash-attention backward. Pre-padded kernel layout, like the
    forward: q/k/v/do (N, H, S, D) with D a 128-lane multiple and S padded to
    the q/kv tile (zero rows/cols); lse and delta ( = rowsum(dO * O), fp32 )
    are (N, H, Sq) padded with zeros. Zero-padded dO rows make every padded
    contribution vanish (ds = p * (dp - delta) = 0), and padded KV columns
    are re-masked to NEG_INF in-kernel exactly as in the forward.

    Returns fp32 (dq (N, H, Sq, D), dk, dv (N, H, Skv, D),
    dbias (B, H, Sq, Skv) | None, dmask_h (N, H, Skv) | None). dmask_h is the
    per-head mask reduction (sum over q of ds) — callers sum over H. Three
    grid sweeps recompute the ds tile in VMEM (dq: KV-innermost; dk/dv + mask
    reduction: q-innermost; bias reduction: bias-group-innermost so the
    (q_tile, kv_tile) accumulator can live in scratch) — or TWO sweeps when
    the bias group is mesh-local (rep == 1): dbias is emitted directly from
    the dq sweep's ds tiles and the bias-reduction sweep is skipped.
    """
    n, h, sq, d = q.shape
    skv = k.shape[2]
    assert sq % q_tile == 0 and skv % kv_tile == 0 and d % LANE == 0, \
        (q.shape, k.shape, q_tile, kv_tile)
    nq, nkv = sq // q_tile, skv // kv_tile

    def specs4(ixmap):
        return pl.BlockSpec((1, 1, q_tile, d), ixmap)

    def qkv_specs(iq_of, jk_of):
        # q/do + lse/delta blocks at the q-tile index, k/v at the kv index.
        return [
            pl.BlockSpec((1, 1, q_tile, d),
                         lambda *g: (g[0], g[1], iq_of(g), 0)),
            pl.BlockSpec((1, 1, kv_tile, d),
                         lambda *g: (g[0], g[1], jk_of(g), 0)),
            pl.BlockSpec((1, 1, kv_tile, d),
                         lambda *g: (g[0], g[1], jk_of(g), 0)),
            pl.BlockSpec((1, 1, q_tile, d),
                         lambda *g: (g[0], g[1], iq_of(g), 0)),
            pl.BlockSpec((1, 1, q_tile),
                         lambda *g: (g[0], g[1], iq_of(g))),
            pl.BlockSpec((1, 1, q_tile),
                         lambda *g: (g[0], g[1], iq_of(g))),
        ]

    rep = 1
    if has_bias:
        assert bias is not None and bias.ndim == 4 and n % bias.shape[0] == 0
        rep = n // bias.shape[0]
    # Mesh-local bias group (rep == 1, e.g. the shard-mapped DAP layout with
    # one bias row per attention row): the dq sweep's ds tiles ARE dbias —
    # emit them as a second output and skip the bias-reduction sweep
    # entirely (3 recompute sweeps -> 2).
    fuse_dbias = has_bias and rep == 1

    base_ops = [q, k, v, do, lse, delta]

    # --- sweep 1: dq (+ dbias when the bias group is mesh-local),
    #     grid (N, H, nq, nkv), KV innermost ---
    in_specs = qkv_specs(lambda g: g[2], lambda g: g[3])
    operands = list(base_ops)
    if has_bias:
        in_specs.append(pl.BlockSpec(
            (1, 1, q_tile, kv_tile),
            lambda i, j, iq, jk: (i // rep, j, iq, jk)))
        operands.append(bias)
    if has_mask:
        assert mask is not None and mask.shape == (n, skv)
        in_specs.append(pl.BlockSpec((1, kv_tile),
                                     lambda i, j, iq, jk: (i, jk)))
        operands.append(mask)
    out_specs = [pl.BlockSpec((1, 1, q_tile, d),
                              lambda i, j, iq, jk: (i, j, iq, 0))]
    out_shape = [jax.ShapeDtypeStruct((n, h, sq, d), jnp.float32)]
    if fuse_dbias:
        out_specs.append(pl.BlockSpec((1, 1, q_tile, kv_tile),
                                      lambda i, j, iq, jk: (i, j, iq, jk)))
        out_shape.append(jax.ShapeDtypeStruct((n, h, sq, skv), jnp.float32))
    outs1 = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, kv_len=kv_len,
                          kv_tile=kv_tile, has_bias=has_bias,
                          has_mask=has_mask, emit_dbias=fuse_dbias),
        grid=(n, h, nq, nkv),
        in_specs=in_specs,
        out_specs=out_specs if fuse_dbias else out_specs[0],
        out_shape=out_shape if fuse_dbias else out_shape[0],
        scratch_shapes=[pltpu.VMEM((q_tile, d), jnp.float32)],
        interpret=interpret,
    )(*operands)
    dq = outs1[0] if fuse_dbias else outs1
    dbias_fused = outs1[1] if fuse_dbias else None

    # --- sweep 2: dk/dv (+ mask reduction), grid (N, H, nkv, nq), q inner ---
    in_specs = qkv_specs(lambda g: g[3], lambda g: g[2])
    operands = list(base_ops)
    if has_bias:
        in_specs.append(pl.BlockSpec(
            (1, 1, q_tile, kv_tile),
            lambda i, j, jk, iq: (i // rep, j, iq, jk)))
        operands.append(bias)
    if has_mask:
        in_specs.append(pl.BlockSpec((1, kv_tile),
                                     lambda i, j, jk, iq: (i, jk)))
        operands.append(mask)
    kv_spec = pl.BlockSpec((1, 1, kv_tile, d),
                           lambda i, j, jk, iq: (i, j, jk, 0))
    out_specs = [kv_spec, kv_spec]
    out_shape = [jax.ShapeDtypeStruct((n, h, skv, d), jnp.float32),
                 jax.ShapeDtypeStruct((n, h, skv, d), jnp.float32)]
    scratch = [pltpu.VMEM((kv_tile, d), jnp.float32),
               pltpu.VMEM((kv_tile, d), jnp.float32)]
    if has_mask:
        out_specs.append(pl.BlockSpec((1, 1, kv_tile),
                                      lambda i, j, jk, iq: (i, j, jk)))
        out_shape.append(jax.ShapeDtypeStruct((n, h, skv), jnp.float32))
        scratch.append(pltpu.VMEM((8, kv_tile), jnp.float32))
    outs = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, kv_len=kv_len,
                          kv_tile=kv_tile, has_bias=has_bias,
                          has_mask=has_mask),
        grid=(n, h, nkv, nq),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
    dk, dv = outs[0], outs[1]
    dmask_h = outs[2] if has_mask else None

    # --- sweep 3: dbias, grid (B, H, nq, nkv, rep), bias group innermost.
    #     Skipped when the dq sweep already emitted dbias (rep == 1). ---
    dbias = None
    if fuse_dbias:
        dbias = dbias_fused
    elif has_bias:
        nb = bias.shape[0]
        in_specs = [
            pl.BlockSpec((1, 1, q_tile, d),
                         lambda b, j, iq, jk, r: (b * rep + r, j, iq, 0)),
            pl.BlockSpec((1, 1, kv_tile, d),
                         lambda b, j, iq, jk, r: (b * rep + r, j, jk, 0)),
            pl.BlockSpec((1, 1, kv_tile, d),
                         lambda b, j, iq, jk, r: (b * rep + r, j, jk, 0)),
            pl.BlockSpec((1, 1, q_tile, d),
                         lambda b, j, iq, jk, r: (b * rep + r, j, iq, 0)),
            pl.BlockSpec((1, 1, q_tile),
                         lambda b, j, iq, jk, r: (b * rep + r, j, iq)),
            pl.BlockSpec((1, 1, q_tile),
                         lambda b, j, iq, jk, r: (b * rep + r, j, iq)),
            pl.BlockSpec((1, 1, q_tile, kv_tile),
                         lambda b, j, iq, jk, r: (b, j, iq, jk)),
        ]
        operands = list(base_ops) + [bias]
        if has_mask:
            in_specs.append(pl.BlockSpec(
                (1, kv_tile), lambda b, j, iq, jk, r: (b * rep + r, jk)))
            operands.append(mask)
        dbias = pl.pallas_call(
            functools.partial(_bwd_dbias_kernel, scale=scale, kv_len=kv_len,
                              kv_tile=kv_tile, has_mask=has_mask),
            grid=(nb, h, nq, nkv, rep),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, q_tile, kv_tile),
                                   lambda b, j, iq, jk, r: (b, j, iq, jk)),
            out_shape=jax.ShapeDtypeStruct((nb, h, sq, skv), jnp.float32),
            scratch_shapes=[pltpu.VMEM((q_tile, kv_tile), jnp.float32)],
            interpret=interpret,
        )(*operands)

    return dq, dk, dv, dbias, dmask_h
