"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. Build a reduced AlphaFold, run folding inference (the paper's model).
2. Run one DAP-style training step.
3. Build an assigned LLM arch and generate tokens through the serving engine.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.alphafold import SMOKE
from repro.core.alphafold import (alphafold_forward, alphafold_train_loss,
                                  init_alphafold)
from repro.data import protein_batches
from repro.models.decoder import init_model
from repro.serving.engine import ServingEngine
from repro.train.loop import make_train_step

# --- 1. AlphaFold inference -------------------------------------------------
print("== AlphaFold (reduced) folding inference ==")
params = init_alphafold(jax.random.PRNGKey(0), SMOKE)
pb = next(protein_batches(batch=1, n_seq=8, n_res=16, seed=0))
batch = {k: jnp.asarray(getattr(pb, k)) for k in
         ("msa", "msa_mask", "residue_index", "aatype", "seq_mask",
          "pseudo_beta", "bert_mask", "true_msa")}
out = alphafold_forward(params, batch, SMOKE)  # recycling included
print("predicted CA coords:", out["coords"].shape,
      "distogram:", out["distogram_logits"].shape)

# --- 2. one training step ----------------------------------------------------
print("== one AlphaFold training step ==")
init_state, train_step = make_train_step(
    lambda p, b, r: alphafold_train_loss(p, b, SMOKE, rng=r), base_lr=1e-3)
state = init_state(params)
state, metrics = jax.jit(train_step)(state, batch, jax.random.PRNGKey(1))
print({k: round(float(v), 3) for k, v in metrics.items()})

# --- 3. LLM serving (assigned architecture) ----------------------------------
print("== qwen2 (reduced) serving ==")
cfg = get_config("qwen2-1.5b", reduced_variant=True)
lm_params = init_model(jax.random.PRNGKey(0), cfg)
engine = ServingEngine(lm_params, cfg, n_slots=2, max_seq=64)
prompt = np.random.default_rng(0).integers(0, cfg.vocab, size=(8,))
req = engine.submit(prompt, max_new_tokens=8, temperature=0.8)
engine.run()
print("prompt:", prompt.tolist())
print("generated:", req.generated)
