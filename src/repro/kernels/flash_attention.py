"""Fused flash-style gated-attention forward Pallas TPU kernel.

Computes ``out = softmax(scale * q @ k^T + bias + mask) @ v`` with an online
softmax over KV tiles: the scores tile lives only in VMEM, so the
``(N, H, R, R)`` scores tensor the paper's §III.B identifies as the cubic
``N_r^3 * H`` memory transient never reaches HBM. HBM traffic per q tile is
linear in the KV tile size instead of quadratic in sequence length — the
fused-attention gap ScaleFold (arXiv 2404.11068) closes on top of FastFold's
kernel set.

Kernel contract (enforced/prepared by ops.fused_attention):

  q, k, v : (N, H, S, D) with D already zero-padded to a 128-lane multiple
            and S padded to the q/kv tile (zero rows — harmless: they attend
            over the real KV range and are sliced off by the caller).
  bias    : (B, H, Sq, Skv) additive, ``N % B == 0`` (each bias batch element
            is shared by N/B consecutive rows of q — the Evoformer pair bias
            shared across the MSA/group axis), or None.
  mask    : (N, Skv) additive fp32 (0 / NEG_INF-style), or None. Mask values
            must be finite (use ~-1e9, not -inf).
  kv_len  : true KV length before padding; padded columns are masked to
            ``NEG_INF`` in-kernel so they never win the max nor add to the sum.

Returns ``out (N, H, Sq, D)`` in the input dtype and the fp32 log-sum-exp
``lse (N, H, Sq)`` that the recompute backward in ops.py needs.

Grid: ``(N, H, Sq/q_tile, Skv/kv_tile)`` with KV innermost. The fp32 running
(m, l, acc) state lives in VMEM scratch across the KV sweep; the output block
is written once on the final KV step (Pallas revisiting semantics keep the
block resident until its index changes). fp32 statistics, MXU GEMMs with
fp32 accumulation (``preferred_element_type``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
NEG_INF = -1e30  # finite: keeps exp(s - m) NaN-free even for all-masked rows


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _flash_kernel(*refs, scale: float, kv_len: int, kv_tile: int,
                  has_bias: bool, has_mask: bool):
    idx = 0
    q_ref = refs[idx]; idx += 1
    k_ref = refs[idx]; idx += 1
    v_ref = refs[idx]; idx += 1
    b_ref = refs[idx] if has_bias else None
    idx += int(has_bias)
    mk_ref = refs[idx] if has_mask else None
    idx += int(has_mask)
    o_ref, lse_ref = refs[idx], refs[idx + 1]
    acc_ref, m_ref, l_ref = refs[idx + 2], refs[idx + 3], refs[idx + 4]

    jk = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                   # (q_tile, d_pad)
    k = k_ref[0, 0]                                   # (kv_tile, d_pad)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                         # (q_tile, kv_tile)
    if b_ref is not None:
        s = s + b_ref[0, 0].astype(jnp.float32)
    if mk_ref is not None:
        s = s + mk_ref[0].astype(jnp.float32)[None, :]
    # Neutralize KV padding: padded columns must not win the max nor
    # contribute to the sum.
    col = jk * kv_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < kv_len, s, NEG_INF)

    m_prev = m_ref[:, :1]                             # (q_tile, 1)
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jk == n_kv - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0, :] = (m_ref[:, :1] + jnp.log(l))[:, 0]


@functools.partial(
    jax.jit,
    static_argnames=("scale", "kv_len", "q_tile", "kv_tile", "has_bias",
                     "has_mask", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
    *,
    scale: float,
    kv_len: int,
    q_tile: int,
    kv_tile: int,
    has_bias: bool = False,
    has_mask: bool = False,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Pre-padded inputs only — see module docstring; use ops.fused_attention."""
    n, h, sq, d = q.shape
    skv = k.shape[2]
    assert sq % q_tile == 0 and skv % kv_tile == 0 and d % LANE == 0, \
        (q.shape, k.shape, q_tile, kv_tile)
    grid = (n, h, sq // q_tile, skv // kv_tile)

    in_specs = [
        pl.BlockSpec((1, 1, q_tile, d), lambda i, j, iq, jk: (i, j, iq, 0)),
        pl.BlockSpec((1, 1, kv_tile, d), lambda i, j, iq, jk: (i, j, jk, 0)),
        pl.BlockSpec((1, 1, kv_tile, d), lambda i, j, iq, jk: (i, j, jk, 0)),
    ]
    operands = [q, k, v]
    if has_bias:
        assert bias is not None and bias.ndim == 4 and n % bias.shape[0] == 0
        rep = n // bias.shape[0]
        in_specs.append(
            pl.BlockSpec((1, 1, q_tile, kv_tile),
                         lambda i, j, iq, jk: (i // rep, j, iq, jk))
        )
        operands.append(bias)
    if has_mask:
        assert mask is not None and mask.shape == (n, skv)
        in_specs.append(
            pl.BlockSpec((1, kv_tile), lambda i, j, iq, jk: (i, jk))
        )
        operands.append(mask)

    kernel = functools.partial(
        _flash_kernel, scale=scale, kv_len=kv_len, kv_tile=kv_tile,
        has_bias=has_bias, has_mask=has_mask,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, q_tile, d), lambda i, j, iq, jk: (i, j, iq, 0)),
            pl.BlockSpec((1, 1, q_tile), lambda i, j, iq, jk: (i, j, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((n, h, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_tile, d), jnp.float32),      # acc
            pltpu.VMEM((q_tile, LANE), jnp.float32),   # running max m
            pltpu.VMEM((q_tile, LANE), jnp.float32),   # running sum l
        ],
        interpret=interpret,
    )(*operands)
