"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: each kernel test sweeps shapes/dtypes and
asserts allclose against these functions. They are also the fallback path used
by ops.py when a shape is outside the kernel's supported envelope.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_ref(
    x: jax.Array,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
    scale: float = 1.0,
) -> jax.Array:
    """softmax(scale*x + bias + mask) over the last axis, fp32 accumulation.

    x:    (N, H, R, C)
    bias: (B, H, R, C) with N % B == 0 — each bias batch element is shared by
          N/B consecutive rows of x (pair bias in Evoformer: B batch elements,
          N = B*s attention groups). (H, R, C) is accepted as B=1.
    mask: (N, C)     additive, broadcast over H, R
    """
    acc = x.astype(jnp.float32) * scale
    if bias is not None:
        if bias.ndim == 3:
            bias = bias[None]
        b = bias.shape[0]
        n = x.shape[0]
        acc = acc.reshape((b, n // b) + acc.shape[1:])
        acc = acc + bias.astype(jnp.float32)[:, None]
        acc = acc.reshape((n,) + acc.shape[2:])
    if mask is not None:
        acc = acc + mask.astype(jnp.float32)[:, None, None, :]
    out = jax.nn.softmax(acc, axis=-1)
    return out.astype(x.dtype)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
    scale: float = 1.0,
) -> tuple[jax.Array, jax.Array]:
    """Scores-materialized oracle for ops.fused_attention.

    q: (N, Sq, H, D); k, v: (N, Skv, H, D)
    bias: (B, H, Sq, Skv), N % B == 0 (each bias batch element shared by N/B
          consecutive rows — Evoformer pair bias), or (H, Sq, Skv) as B=1.
    mask: (N, Skv) additive fp32, broadcast over H and Sq.

    Returns (out (N, Sq, H, D) in q.dtype, lse (N, H, Sq) fp32). This is the
    exact computation the fused kernel performs tile-wise; it materializes the
    full (N, H, Sq, Skv) scores tensor and is the A/B baseline + fallback.
    """
    n, sq, h, d = q.shape
    s = jnp.einsum("nqhd,nkhd->nhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if bias is not None:
        if bias.ndim == 3:
            bias = bias[None]
        b = bias.shape[0]
        s = s.reshape((b, n // b) + s.shape[1:])
        s = s + bias.astype(jnp.float32)[:, None]
        s = s.reshape((n,) + s.shape[2:])
    if mask is not None:
        s = s + mask.astype(jnp.float32)[:, None, None, :]
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    ex = jnp.exp(s - m)
    l = jnp.sum(ex, axis=-1, keepdims=True)
    probs = (ex / jnp.maximum(l, 1e-30)).astype(q.dtype)
    out = jnp.einsum("nhqk,nkhd->nqhd", probs, v,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]
    return out, lse


def attention_bwd_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    bias: jax.Array | None,
    mask: jax.Array | None,
    g: jax.Array,
    scale: float = 1.0,
):
    """Autodiff gradients of attention_ref's output under cotangent ``g`` —
    the oracle for both legs of ops._attn_bwd (the jnp KV-scan and the fused
    flash_attention_bwd_pallas kernel). Returns (dq, dk, dv, dbias | None,
    dmask | None)."""
    diff = [q, k, v]
    if bias is not None:
        diff.append(bias)
    if mask is not None:
        diff.append(mask)

    def f(*args):
        b_ = args[3] if bias is not None else None
        m_ = args[3 + (bias is not None)] if mask is not None else None
        return attention_ref(args[0], args[1], args[2], b_, m_, scale)[0]

    _, vjp = jax.vjp(f, *diff)
    grads = list(vjp(g))
    if bias is None:
        grads.insert(3, None)
    if mask is None:
        grads.append(None)
    return tuple(grads)


def triangle_mult_ref(
    a_lin: jax.Array,
    ga: jax.Array,
    mask: jax.Array,
    b_full: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    w_out: jax.Array,
    b_out: jax.Array,
    g_lin: jax.Array,
    g_bias: jax.Array,
    eps: float = 1e-5,
) -> jax.Array:
    """Materialized oracle for ops.fused_triangle_mult (the full fused
    triangular multiplicative update chain).

    a_lin, ga: (B, I, K, C) left projection / gate logits; mask: (B, I, K);
    b_full: (B, J, K, C) gated+masked right operand (gathered under DAP);
    gamma/beta: (C,) output LN; w_out: (C, D), b_out: (D,) output projection;
    g_lin: (B, I, J, D) output-gate logits (pre-bias), g_bias: (D,).

    out = sigmoid(g_lin + g_bias) * (LN_c(sum_k a·b) @ w_out + b_out) with
    a = (a_lin * sigmoid(ga)) * mask — fp32 accumulation/statistics, GEMM
    operands in the compute dtype. Materializes the full (B, I, J, C) fp32
    product; the fused legs keep it tile-bounded.
    """
    f32 = jnp.float32
    a = (a_lin.astype(f32) * jax.nn.sigmoid(ga.astype(f32))
         ).astype(a_lin.dtype) * mask.astype(a_lin.dtype)[..., None]
    o = jnp.einsum("bikc,bjkc->bijc", a, b_full, preferred_element_type=f32)
    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(o - mean), axis=-1, keepdims=True)
    y = ((o - mean) * jax.lax.rsqrt(var + eps) * gamma.astype(f32)
         + beta.astype(f32)).astype(a.dtype)
    z = jnp.einsum("bijc,cd->bijd", y, w_out.astype(a.dtype),
                   preferred_element_type=f32) + b_out.astype(f32)
    s = jax.nn.sigmoid(g_lin.astype(f32) + g_bias.astype(f32))
    return (s * z).astype(g_lin.dtype)


def outer_product_mean_ref(
    a: jax.Array,
    b_full: jax.Array,
    mask_a: jax.Array,
    mask_b: jax.Array,
    w: jax.Array,
    bias: jax.Array,
) -> jax.Array:
    """Materialized oracle for ops.fused_outer_product_mean.

    a: (B, S, I, C), b_full: (B, S, J, C) masked projections (b gathered
    under DAP); mask_a: (B, S, I), mask_b: (B, S, J); w: (C*C, D), bias (D,).

    out[b,i,j] = (vec(sum_s a_si ⊗ b_sj) / (norm_ij + 1e-3)) @ w + bias with
    norm = sum_s mask_a·mask_b — fp32 outer product and normalization.
    Materializes the full (B, I, J, C, C) transient; the fused legs keep it
    tile-bounded.
    """
    f32 = jnp.float32
    o = jnp.einsum("bsic,bsjd->bijcd", a, b_full, preferred_element_type=f32)
    norm = jnp.einsum("bsi,bsj->bij", mask_a.astype(f32), mask_b.astype(f32))
    ov = (o / (norm[..., None, None] + 1e-3)).astype(a.dtype)
    out = jnp.einsum("bijx,xd->bijd", ov.reshape(ov.shape[:3] + (-1,)),
                     w.astype(a.dtype), preferred_element_type=f32)
    return (out + bias.astype(f32)).astype(a.dtype)


def layer_norm_ref(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    eps: float = 1e-5,
) -> jax.Array:
    """LayerNorm over the last axis with affine, fp32 statistics."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return y.astype(x.dtype)


def bias_sigmoid_mul_ref(g: jax.Array, bg: jax.Array, v: jax.Array) -> jax.Array:
    """sigmoid(g + bg) * v — the Evoformer gating fusion (paper §IV.A JIT fusion)."""
    gf = g.astype(jnp.float32) + bg.astype(jnp.float32)
    return (jax.nn.sigmoid(gf) * v.astype(jnp.float32)).astype(v.dtype)


def bias_dropout_add_ref(
    x: jax.Array,
    b: jax.Array,
    residual: jax.Array,
    keep: jax.Array | None,
    rate: float,
) -> jax.Array:
    """residual + dropout(x + b, rate). `keep` is a float 0/1 mask (same shape
    as x); keep=None => no dropout."""
    y = x.astype(jnp.float32) + b.astype(jnp.float32)
    if keep is not None and rate > 0.0:
        y = y * keep.astype(jnp.float32) / (1.0 - rate)
    return (residual.astype(jnp.float32) + y).astype(residual.dtype)
