"""Training step factory: loss -> grads (bf16 compute, fp32 reduce) ->
global-norm clip -> LR schedule -> optimizer -> new state. Supports gradient
accumulation (the paper's micro-batching for DP scaling) and composes with
pjit shardings supplied by parallel/plan.py.

Robustness: ``guard_nonfinite`` (default on) skips the parameter/optimizer
update whenever the global grad norm is non-finite (one bad batch or a
transient numeric fault must not poison the whole run — at ParaFold scale a
single NaN step otherwise costs the job). The guard is a where-select on
the already-computed update, so healthy steps are *bit-identical* with the
guard on or off (trace-time overhead only); skipped steps still advance
``state.step`` (the LR schedule keeps its wall-clock meaning) and report
``metrics['nonfinite_skips'] = 1.0`` so callers can count them.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim import clip_by_global_norm, make_optimizer
from repro.optim.schedules import cosine_schedule
from repro.train.state import TrainState, make_train_state


def make_train_step(
    loss_fn: Callable,                    # (params, batch, rng) -> (loss, metrics)
    *,
    optimizer: str = "adamw",
    base_lr: float = 1e-3,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    weight_decay: float = 0.0,
    clip_norm: float = 1.0,
    accum_steps: int = 1,
    state_dtype=jnp.float32,
    guard_nonfinite: bool = True,
):
    opt_init_raw, opt_update = make_optimizer(optimizer)
    opt_init = partial(opt_init_raw, state_dtype=state_dtype)

    def init_state(params) -> TrainState:
        return make_train_state(params, opt_init)

    def compute_grads(params, batch, rng):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, rng)
        return loss, metrics, grads

    def train_step(state: TrainState, batch, rng=None):
        """One optimizer step: ``(state, batch, rng) -> (state, metrics)``.

        Stable metrics-key contract — every key below is present on EVERY
        step (never conditionally), so downstream aggregation (obs
        ``train_step`` events, CSV logs) sees a fixed schema:

            loss             scalar training loss (micro-batch mean under
                             gradient accumulation)
            grad_norm        pre-clip global L2 norm of the gradients
            lr               this step's scheduled learning rate
            nonfinite_skips  1.0 when the non-finite guard discarded the
                             update, else 0.0 (always 0.0 with
                             ``guard_nonfinite=False``)

        ``loss_fn`` aux metrics ride along unchanged; new always-present
        keys may be added, but existing keys are never renamed, removed,
        or made conditional.
        """
        if accum_steps == 1:
            loss, metrics, grads = compute_grads(state.params, batch, rng)
        else:
            # micro-batching: batch leading dim must divide accum_steps
            def micro(i, carry):
                acc, loss_acc = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // accum_steps),
                        x.shape[0] // accum_steps, axis=0), batch)
                r = jax.random.fold_in(rng, i) if rng is not None else None
                loss, metrics, grads = compute_grads(state.params, mb, r)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                   acc, grads)
                return acc, loss_acc + loss

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, loss_sum = jax.lax.fori_loop(
                0, accum_steps, micro, (zeros, jnp.zeros((), jnp.float32)))
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = {"loss": loss}

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        metrics = dict(metrics)
        # Contract: "loss" is always present, whether or not the loss_fn's
        # aux dict reports one of its own (an aux "loss" wins — it may be
        # the unscaled/per-token variant the caller prefers to log).
        metrics.setdefault("loss", loss)
        if guard_nonfinite:
            # One non-finite leaf makes gnorm (the global L2) non-finite, so
            # this single scalar guards the whole grad tree. Feed zeros to
            # the optimizer so NaNs never propagate, then discard the
            # update via where-select — when healthy, where(True, x, .) is
            # x, bit for bit.
            ok = jnp.isfinite(gnorm)
            grads = jax.tree.map(
                lambda g: jnp.where(ok, g, jnp.zeros_like(g)), grads)
            metrics["nonfinite_skips"] = (~ok).astype(jnp.float32)
        else:
            # Guard off: the key is still reported (constant 0.0) so the
            # metrics schema is never ragged across configurations.
            metrics["nonfinite_skips"] = jnp.zeros((), jnp.float32)
        lr = cosine_schedule(state.step, base_lr, warmup_steps, total_steps)
        new_params, new_opt = opt_update(
            state.params, grads, state.opt_state, lr,
            weight_decay=weight_decay)
        if guard_nonfinite:
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params, state.params)
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_opt, state.opt_state)
        metrics.update({"grad_norm": gnorm, "lr": lr})
        return TrainState(state.step + 1, new_params, new_opt), metrics

    return init_state, train_step


def instrument_train_step(step_fn, *, tokens_per_step: float | None = None,
                          metric_keys=("loss", "grad_norm",
                                       "nonfinite_skips")):
    """Wrap an (optionally jitted) ``train_step`` so each call emits one
    obs ``train_step`` event when a tracer is scoped — and is the identity
    call (same objects returned, no added work beyond one contextvar read)
    when none is.

    Host-side wrapper by design: ``make_train_step`` callers jit the step
    themselves, and anything inside the jitted function would run once at
    trace time, not per step. The wrapper measures the host *dispatch*
    time only (no ``block_until_ready`` — the hot path gains no sync) and
    records the selected metric scalars as live device arrays; they are
    resolved to floats when the tracer serializes, off the hot path.
    ``tokens_per_step`` (e.g. batch * seq_len) rides along for throughput
    aggregation."""
    from repro.obs.trace import current_tracer, monotonic_ns

    step_counter = [0]

    def instrumented(state, batch, rng=None):
        tr = current_tracer()
        if tr is None:
            return step_fn(state, batch, rng)
        t0 = monotonic_ns()
        state, metrics = step_fn(state, batch, rng)
        dur = monotonic_ns() - t0
        step_counter[0] += 1
        tr.emit("train_step", "train_step", step=step_counter[0],
                dur_ns=dur, tokens=tokens_per_step,
                metrics={k: metrics[k] for k in metric_keys
                         if k in metrics})
        return state, metrics

    return instrumented
