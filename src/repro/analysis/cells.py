"""The contract matrix: (config, ExecutionPlan preset, mesh) cells.

Each cell lowers + compiles one program the repo's invariants were won on —
the 2-block Evoformer stack under GspmdDist (all four attention sites,
forward and backward), the shard-mapped fused triangle/OPM ops, the reduced
2-block AlphaFold train-loss dry-run, and the paper-faithful DAP shard_map
stack (whose jaxpr is also counted primitive-by-primitive) — and evaluates
the contracts from repro/analysis/contracts.py against the artifact.

Shapes are the distributed suite's (small enough to compile on the CPU CI
host in seconds, sharded the same way production is). The per-cell
``PeakBytesWithin`` factors and ``CollectiveBudget`` budgets are calibrated
against the checked-in BENCH_contracts.json baseline: the factor brackets
the measured modeled/compiled ratio with ~2x headroom, so a regression that
doubles the compiled peak (a rematerialized transient, a lost tiling) or
doubles the collective count trips the gate while XLA-version jitter does
not. This module imports jax — the runner (`__main__.py`) parses args and
forces the host device count BEFORE importing it.

NOTE: launch/dryrun.py force-sets a 512-device XLA flag at import time;
this module deliberately builds its own reduced AlphaFold cell instead of
importing it.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.analysis.contracts import (
    CollectiveBudget,
    CompiledArtifact,
    NoInvoluntaryRemat,
    NoMergedAllGather,
    PeakBytesWithin,
    check_all,
)
from repro.core.dist import GspmdDist
from repro.core.evoformer import (
    EvoformerConfig,
    evoformer_stack,
    init_evoformer_stack,
)
from repro.exec.plan import preset, use_plan
from repro.kernels import ops
from repro.launch.mesh import _mesh
from repro.memory.autochunk import (
    modeled_evoformer_peak,
    opm_transient_bytes,
    triangle_transient_bytes,
)

# Evoformer cell config/shapes == the distributed suite's (s and r divide
# every tested model-axis size; compiles in seconds on CPU).
CFG = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2,
                      head_dim=8, opm_dim=8, tri_mult_dim=16, n_blocks=2)
B, S, R = 2, 8, 16

# Per-cell PeakBytesWithin factors, calibrated on the BENCH_contracts.json
# baseline (see module docstring). The AutoChunk model is a dominant-term
# activation model: at these CI shapes parameters/outputs are a visible
# fraction of XLA's peak and backward passes double-count nothing, so the
# bracket is a factor, not a percentage. Forward cells sit closest to the
# model; grad cells and the full AlphaFold dry-run (structure module + heads
# outside the model) get looser brackets.
PEAK_FACTORS = {
    "evoformer_fwd": 4.0,      # measured ratio 1.16-1.37 (oracle/default)
    "evoformer_grad": 48.0,    # fwd-activation model vs full bwd: 19-22x
    "triangle_opm": 4.0,       # measured 0.67-0.76 (model slightly high)
    "alphafold_dryrun": 32.0,  # model covers the Evoformer only: 9.9-10.0x
    "dap_stack": 4.0,          # measured 0.67-1.21
}

# Per-cell static collective budgets (ops per traced block — the layer scan
# body is traced once, so the HLO count IS the per-block count). Calibrated
# the same way: measured count + ~2x headroom. Paper Table III's DAP budget
# is 4 all_to_all + a handful of row gathers per block; GSPMD adds resharding
# collectives around the shard_mapped kernels.
COLLECTIVE_BUDGETS = {
    "evoformer_fwd": 48,        # measured 19-22 static ops
    "evoformer_grad": 256,      # measured 142-168 (bwd resharding)
    "triangle_opm": 8,          # measured 1
    "alphafold_dryrun": 384,    # measured 238-266
    "dap_stack": 32,            # measured 15
    "dap_jaxpr": 32,            # measured 15 explicit primitives
}


@dataclass
class CellResult:
    artifact: CompiledArtifact
    contracts: tuple
    modeled_bytes: int | None = None


def _mesh_ctx(mesh):
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def _compile_artifact(name: str, fn, *args) -> CompiledArtifact:
    compiled = jax.jit(fn).lower(*args).compile()
    mem = compiled.memory_analysis()
    peak = None
    if mem is not None:
        peak = int(getattr(mem, "peak_memory_in_bytes", 0)
                   or getattr(mem, "temp_size_in_bytes", 0)) or None
    return CompiledArtifact(name, compiled.as_text(), peak)


def _fused_under_plan() -> bool:
    """Whether the current plan routes the Evoformer through the fused
    kernels (the AutoChunk model's ``fused`` axis) — same probe
    resolve_evoformer_config uses, at this cell's attention shape."""
    return ops.fused_attention_supported(
        (B, S, R, CFG.msa_heads, CFG.head_dim), kv_len=R,
        dtype=CFG.compute_dtype)


def _evo_inputs():
    msa = jax.random.normal(jax.random.PRNGKey(1), (B, S, R, CFG.d_msa))
    pair = jax.random.normal(jax.random.PRNGKey(2), (B, R, R, CFG.d_pair))
    masks = (jnp.ones((B, S, R)), jnp.ones((B, R)), jnp.ones((B, R, R)))
    return msa, pair, masks


# Legit rank-3+ all-gathers in these programs all lead with B (=2); a lead of
# B*S or B*R is the flatten-forced-gather signature. min_rank=3 covers both
# the attention (rank-4) and triangle/OPM (rank-3) merge shapes.
_EVO_MERGED = frozenset({B * S, B * R})


def _evo_contracts(cell: str, modeled: int | None):
    cs = [NoMergedAllGather(_EVO_MERGED, min_rank=3),
          NoInvoluntaryRemat(),
          CollectiveBudget(COLLECTIVE_BUDGETS[cell])]
    if modeled is not None:
        cs.append(PeakBytesWithin(modeled, PEAK_FACTORS[cell]))
    return tuple(cs)


def cell_evoformer_fwd(pname: str, mesh) -> list[CellResult]:
    """2-block Evoformer forward under GspmdDist — the four attention sites
    + both triangle updates + OPM, shard-mapped over the model axis."""
    n_model = mesh.shape["model"]
    msa, pair, masks = _evo_inputs()
    params = init_evoformer_stack(jax.random.PRNGKey(0), CFG)
    dist = GspmdDist(mesh=mesh, axis="model")
    with use_plan(preset(pname)), _mesh_ctx(mesh):
        art = _compile_artifact(
            f"evoformer_fwd/{pname}",
            lambda p: evoformer_stack(p, msa, pair, *masks, dist=dist,
                                      cfg=CFG, remat=False), params)
        modeled = modeled_evoformer_peak(CFG, batch=B, n_seq=S, n_res=R,
                                         dap=n_model,
                                         fused=_fused_under_plan())
    return [CellResult(art, _evo_contracts("evoformer_fwd", modeled),
                       modeled)]


def cell_evoformer_grad(pname: str, mesh) -> list[CellResult]:
    """Same stack, jit(grad(...)): the backward's recompute regions are where
    sharding propagation historically lost the group dim."""
    n_model = mesh.shape["model"]
    msa, pair, masks = _evo_inputs()
    params = init_evoformer_stack(jax.random.PRNGKey(0), CFG)
    dist = GspmdDist(mesh=mesh, axis="model")

    def loss(p):
        m, z = evoformer_stack(p, msa, pair, *masks, dist=dist, cfg=CFG,
                               remat=False)
        return jnp.sum(m ** 2) + jnp.sum(z ** 2)

    with use_plan(preset(pname)), _mesh_ctx(mesh):
        art = _compile_artifact(f"evoformer_grad/{pname}", jax.grad(loss),
                                params)
        modeled = modeled_evoformer_peak(CFG, batch=B, n_seq=S, n_res=R,
                                         dap=n_model,
                                         fused=_fused_under_plan())
    return [CellResult(art, _evo_contracts("evoformer_grad", modeled),
                       modeled)]


def cell_triangle_opm(pname: str, mesh) -> list[CellResult]:
    """Shard-mapped fused triangle-mult (fwd + grad) and OPM (fwd) as the
    distributed suite drives them; the three programs' HLO is checked as one
    artifact with peak = the max over the three."""
    B2, I, K, C, D, S2 = 2, 16, 16, 16, 12, 8
    c_opm = 8
    ks = jax.random.split(jax.random.PRNGKey(0), 12)
    a_lin = jax.random.normal(ks[0], (B2, I, K, C))
    ga = jax.random.normal(ks[1], (B2, I, K, C))
    mask = jax.random.bernoulli(ks[2], 0.7, (B2, I, K)).astype(jnp.float32)
    b_full = jax.random.normal(ks[3], (B2, I, K, C))
    gamma = jax.random.normal(ks[4], (C,))
    beta = jax.random.normal(ks[5], (C,))
    w_out = jax.random.normal(ks[6], (C, D))
    b_out = jax.random.normal(ks[7], (D,))
    g_lin = jax.random.normal(ks[8], (B2, I, I, D))
    g_bias = jax.random.normal(ks[9], (D,))
    oa = jax.random.normal(ks[10], (B2, S2, I, c_opm))
    ob = jax.random.normal(ks[11], (B2, S2, I, c_opm))
    oma = jnp.ones((B2, S2, I))
    omb = jnp.ones((B2, S2, I))
    ow = jax.random.normal(ks[2], (c_opm * c_opm, D))
    obias = jax.random.normal(ks[3], (D,))

    dist = GspmdDist(mesh=mesh, axis="model")

    def tri(a, b):
        return dist.sharded_triangle(a, ga, mask, b, gamma, beta, w_out,
                                     b_out, g_lin, g_bias, tile=4)

    def opm(a, b):
        return dist.sharded_opm(a, b, oma, omb, ow, obias, tile=4)

    with use_plan(preset(pname)), _mesh_ctx(mesh):
        arts = [
            _compile_artifact("tri_fwd", tri, a_lin, b_full),
            _compile_artifact(
                "tri_grad",
                jax.grad(lambda a, b: jnp.sum(tri(a, b) ** 2),
                         argnums=(0, 1)), a_lin, b_full),
            _compile_artifact("opm_fwd", opm, oa, ob),
        ]
        fused = preset(pname).kernels.enabled
    peaks = [a.peak_bytes for a in arts if a.peak_bytes]
    art = CompiledArtifact(f"triangle_opm/{pname}",
                           "\n".join(a.hlo_text for a in arts),
                           max(peaks) if peaks else None)
    modeled = max(
        B2 * triangle_transient_bytes(I, K, C, tile=4, fused=fused,
                                      dtype_bytes=4),
        B2 * opm_transient_bytes(I, I, S2, c_opm, tile=4, fused=fused,
                                 dtype_bytes=4),
    )
    contracts = [NoMergedAllGather(frozenset({B2 * I}), min_rank=3),
                 NoInvoluntaryRemat(),
                 CollectiveBudget(COLLECTIVE_BUDGETS["triangle_opm"]),
                 PeakBytesWithin(modeled, PEAK_FACTORS["triangle_opm"])]
    return [CellResult(art, tuple(contracts), modeled)]


def cell_alphafold_dryrun(pname: str, mesh) -> list[CellResult]:
    """Reduced 2-block AlphaFold train-loss gradient under GspmdDist — the
    GSPMD dry-run's program shape (embedders + recycling + Evoformer +
    structure module + heads), built here directly so the 512-device
    launch/dryrun module is never imported."""
    from repro.configs.alphafold import SMOKE
    from repro.core.alphafold import alphafold_train_loss, init_alphafold
    from repro.data import protein_batches
    from repro.memory.autochunk import resolve_evoformer_config

    n_model = mesh.shape["model"]
    pb = next(protein_batches(batch=B, n_seq=S, n_res=R, seed=0))
    batch = {k: jnp.asarray(getattr(pb, k)) for k in
             ("msa", "msa_mask", "residue_index", "aatype", "seq_mask",
              "pseudo_beta", "bert_mask", "true_msa")}
    params = init_alphafold(jax.random.PRNGKey(0), SMOKE)
    dist = GspmdDist(mesh=mesh, axis="model")

    def loss(p):
        out = alphafold_train_loss(p, batch, SMOKE,
                                   rng=jax.random.PRNGKey(1), dist=dist)
        return out[0] if isinstance(out, tuple) else out

    with use_plan(preset(pname)), _mesh_ctx(mesh):
        art = _compile_artifact(f"alphafold_dryrun/{pname}", jax.grad(loss),
                                params)
        evo_cfg = resolve_evoformer_config(SMOKE.evoformer, batch=B,
                                           n_seq=S, n_res=R, dap=n_model)
        modeled = modeled_evoformer_peak(evo_cfg, batch=B, n_seq=S, n_res=R,
                                         dap=n_model,
                                         fused=_fused_under_plan())
    return [CellResult(art, _evo_contracts("alphafold_dryrun", modeled),
                       modeled)]


# jax collective primitive names (jaxpr view of the same budget).
_JAXPR_COLLECTIVES = frozenset({
    "all_to_all", "all_gather", "psum", "psum_scatter", "reduce_scatter",
    "ppermute", "all_reduce", "collective_permute",
})


def count_jaxpr_collectives(jaxpr) -> dict[str, int]:
    """Static collective-primitive counts over a (Closed)Jaxpr, recursing
    into every sub-jaxpr (scan/shard_map/cond bodies are traced once, so —
    like the HLO count — this is a per-block number)."""
    counts: dict[str, int] = {}

    def sub_jaxprs(value):
        if hasattr(value, "jaxpr") and hasattr(value, "consts"):
            yield value.jaxpr                    # ClosedJaxpr
        elif hasattr(value, "eqns"):
            yield value                          # Jaxpr
        elif isinstance(value, (tuple, list)):
            for v in value:
                yield from sub_jaxprs(v)

    def walk(j):
        for eqn in j.eqns:
            name = eqn.primitive.name
            if name in _JAXPR_COLLECTIVES:
                counts[name] = counts.get(name, 0) + 1
            for v in eqn.params.values():
                for sj in sub_jaxprs(v):
                    walk(sj)

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return counts


def cell_dap_stack(pname: str, mesh) -> list[CellResult]:
    """Paper-faithful DAP shard_map stack: the compiled artifact carries the
    HLO/memory contracts; a second artifact counts the jaxpr's explicit
    collective primitives against the paper-Table-III budget."""
    from repro.core.dap import dap_evoformer_stack, shard_dap_inputs

    n_model = mesh.shape["model"]
    msa, pair, masks = _evo_inputs()
    params = init_evoformer_stack(jax.random.PRNGKey(0), CFG)
    with use_plan(preset(pname)), _mesh_ctx(mesh):
        fn = dap_evoformer_stack(mesh, CFG, remat=False)
        args = shard_dap_inputs(mesh, msa, pair, *masks)
        art = _compile_artifact(f"dap_stack/{pname}", fn, params, *args)
        jaxpr_counts = count_jaxpr_collectives(
            jax.make_jaxpr(fn)(params, *args))
        modeled = modeled_evoformer_peak(CFG, batch=B, n_seq=S, n_res=R,
                                         dap=n_model,
                                         fused=_fused_under_plan())
    jaxpr_art = CompiledArtifact(f"dap_jaxpr/{pname}",
                                 collective_counts=jaxpr_counts)
    return [
        CellResult(art, _evo_contracts("dap_stack", modeled), modeled),
        CellResult(jaxpr_art,
                   (CollectiveBudget(COLLECTIVE_BUDGETS["dap_jaxpr"]),)),
    ]


CELLS = (cell_evoformer_fwd, cell_evoformer_grad, cell_triangle_opm,
         cell_alphafold_dryrun, cell_dap_stack)


def run_matrix(preset_names=("default", "oracle"), cells=CELLS):
    """Evaluate every cell under every preset. Returns (violations, rows):
    rows are the BENCH_contracts.json records (modeled vs compiled peak,
    static collective counts, contract verdicts) in a stable order."""
    mesh = _mesh((1, len(jax.devices())), ("data", "model"))
    violations, rows = [], []
    for pname in preset_names:
        for cell in cells:
            for res in cell(pname, mesh):
                v = check_all(res.contracts, res.artifact)
                violations.extend(v)
                peak = res.artifact.peak_bytes
                rows.append({
                    "cell": res.artifact.name,
                    "preset": pname,
                    "modeled_bytes": res.modeled_bytes,
                    "compiled_peak_bytes": peak,
                    "ratio": (round(peak / res.modeled_bytes, 3)
                              if peak and res.modeled_bytes else None),
                    "collectives": dict(sorted(
                        res.artifact.counts().items())),
                    "contracts": [c.name for c in res.contracts],
                    "violations": [x.render() for x in v],
                })
    return violations, rows
