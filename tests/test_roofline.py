"""Roofline HLO-parser unit tests on synthetic + real compiled programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import analysis as A


SYNTH = """\
HloModule test

%region_0.2 (arg_tuple.1: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = f32[128,128]{1,0} parameter(0)
  %dot.1 = f32[128,128]{1,0} dot(%p, %p), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag.1 = f32[128,128]{1,0} all-gather(%p), replica_groups=[2,4]<=[8], dimensions={0}
}

ENTRY %main (x: f32[128,128]) -> f32[128,128] {
  %x = f32[128,128]{1,0} parameter(0)
  %while.5 = (s32[], f32[128,128]{1,0}) while(%x), condition=%c, body=%region_0.2, backend_config={"known_trip_count":{"n":"10"}}
  %ar.1 = f32[64,64]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}
}
"""


def test_shape_bytes():
    assert A._shape_bytes("f32[128,128]") == 128 * 128 * 4
    assert A._shape_bytes("bf16[2,4,8]") == 2 * 4 * 8 * 2
    assert A._shape_bytes("(f32[4], bf16[8])") == 16 + 16
    assert A._shape_bytes("pred[16]") == 16


def test_synthetic_collectives_scaled_by_trip_count():
    stats = A.parse_collectives(SYNTH, default_group=8)
    # all-gather inside 10-trip loop: payload = 10 * 64KB
    assert stats.counts["all-gather"] == 1
    np.testing.assert_allclose(stats.payload_bytes["all-gather"],
                               10 * 128 * 128 * 4)
    # all-reduce outside loop, group of 4: wire factor 2*(3/4)
    np.testing.assert_allclose(
        stats.payload_bytes["all-reduce"], 64 * 64 * 4)
    expected_wire = (10 * 128 * 128 * 4) * (3 / 4) + (64 * 64 * 4) * 1.5
    np.testing.assert_allclose(stats.wire_bytes, expected_wire)


def test_synthetic_dot_flops_scaled():
    flops, _ = A.hlo_cost(SYNTH)
    np.testing.assert_allclose(flops, 10 * 2 * 128 ** 3)


def test_real_program_flops_match_known_matmul():
    n, k, m = 64, 32, 48
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((n, k), jnp.float32),
        jax.ShapeDtypeStruct((k, m), jnp.float32)).compile()
    flops, bts = A.hlo_cost(c.as_text())
    np.testing.assert_allclose(flops, 2 * n * k * m)
    assert bts >= (n * k + k * m + n * m) * 4  # at least one pass of I/O


def test_real_scan_trip_scaling():
    L = 12
    ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    x0 = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(ws, x):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    c = jax.jit(f).lower(ws, x0).compile()
    flops, _ = A.hlo_cost(c.as_text())
    np.testing.assert_allclose(flops, L * 2 * 64 ** 3, rtol=0.01)


def test_roofline_terms_and_bottleneck():
    r = A.Roofline(flops=197e12, hbm_bytes=819e9 * 2, wire_bytes=0.0,
                   chips=1, peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)
    np.testing.assert_allclose(r.t_compute, 1.0)
    np.testing.assert_allclose(r.t_memory, 2.0)
    assert r.bottleneck == "memory"


def test_model_flops():
    from repro.configs.base import ShapeConfig
    train = ShapeConfig("t", 1024, 8, "train")
    dec = ShapeConfig("d", 1024, 8, "decode")
    assert A.model_flops(train, 1e9) == 6e9 * 8 * 1024
    assert A.model_flops(dec, 1e9) == 2e9 * 8
