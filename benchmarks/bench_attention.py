"""Fused flash-attention kernel A/B at Evoformer shapes.

Three executions of the same gated-attention math
(``softmax(scale*qk^T + bias + mask) @ v``):

  fused         ops.fused_attention — online softmax over KV tiles, scores
                never in HBM (this PR's kernel).
  materialized  scores einsum -> fused-softmax kernel -> probs einsum (the
                pre-kernel Evoformer path, kept behind REPRO_DISABLE_KERNELS).
  chunked       paper-§V.C chunking technique: groups processed sequentially
                via lax.map over the materialized path.

For each shape: forward and forward+backward wall time, plus the modeled peak
attention-transient bytes (repro.memory.autochunk.attention_transient_bytes)
— the fused column scales with the KV tile, the materialized column with
R^2. On non-TPU backends the fused path runs its XLA-native online-softmax
leg (interpret-mode Pallas only under REPRO_PALLAS_INTERPRET=1, the
kernel-validation leg); the bytes columns are backend-independent.

Backward-leg A/B (``attn_bwd_*`` rows): the fused path's *active* backward
(the fused Pallas kernel on TPU / under the interpret plan; the jnp KV-scan
elsewhere) vs the jnp KV-scan pinned via a
``use_plan(KernelPolicy(attn_bwd='scan'))`` scope — a data value, not a
module-global mutation, so interleaved A/B cells cannot leak state into each
other. The acceptance gate is active-bwd no slower than the scan at
Evoformer shapes on the kernel's target backend.
"""
import functools

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.exec.plan import current_plan, use_plan
from repro.kernels import ops
from repro.layers.attention import evoformer_attention
from repro.memory.autochunk import attention_transient_bytes

KV_TILE = 128


def _scan_bwd_plan():
    """The A/B cell's scan-backward plan: identical to the AMBIENT plan at
    run time (not import time — a driver may scope use_plan around run())
    except the attention backward is pinned to the jnp KV-scan recompute."""
    return current_plan().with_kernels(attn_bwd="scan")


def _inputs(g, h, s, d, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (g, s, h, d), dtype)
    k = jax.random.normal(ks[1], (g, s, h, d), dtype)
    v = jax.random.normal(ks[2], (g, s, h, d), dtype)
    bias = jax.random.normal(ks[3], (1, h, s, s), dtype)
    mask = jnp.where(jax.random.bernoulli(ks[4], 0.9, (g, s)), 0.0,
                     -1e9).astype(jnp.float32)
    return q, k, v, bias, mask


def _materialized(q, k, v, bias, mask):
    # The repo's actual scores-materialized baseline (same 1/sqrt(hd) scale
    # and bias/mask contract as ops.fused_attention).
    return evoformer_attention(q, k, v, bias=bias, mask=mask)


def _chunked(q, k, v, bias, mask, chunk):
    n = q.shape[0]
    nc = n // chunk

    def split(t):
        return t.reshape((nc, chunk) + t.shape[1:])

    out = jax.lax.map(
        lambda args: _materialized(args[0], args[1], args[2], bias, args[3]),
        (split(q), split(k), split(v), split(mask)))
    return out.reshape(q.shape)


def run():
    for (g, h, s, d) in [(8, 4, 128, 32), (4, 4, 256, 32)]:
        q, k, v, bias, mask = _inputs(g, h, s, d)
        variants = {
            "fused": jax.jit(functools.partial(
                ops.fused_attention, kv_tile=KV_TILE)),
            "materialized": jax.jit(_materialized),
            "chunked": jax.jit(functools.partial(
                _chunked, chunk=max(g // 4, 1))),
        }
        times = {}
        for name, fn in variants.items():
            if name == "fused":
                f = lambda: fn(q, k, v, bias=bias, mask=mask)
                gf = jax.jit(jax.grad(lambda q_, k_, v_: jnp.sum(
                    fn(q_, k_, v_, bias=bias, mask=mask) ** 2),
                    argnums=(0, 1, 2)))
            else:
                f = lambda: fn(q, k, v, bias, mask)
                gf = jax.jit(jax.grad(lambda q_, k_, v_: jnp.sum(
                    fn(q_, k_, v_, bias, mask) ** 2), argnums=(0, 1, 2)))
            fused = name == "fused"
            geff = max(g // 4, 1) if name == "chunked" else g
            peak = attention_transient_bytes(
                geff, h, s, d, kv_tile=KV_TILE if fused else 0, fused=fused,
                dtype_bytes=4)
            t_f = time_fn(lambda *_: f(), None, iters=5, warmup=2)
            times[(name, "fwd")] = t_f
            csv_row(f"attn_{name}_fwd_g{g}s{s}", t_f,
                    f"peak_attn_bytes={peak}")
            t_b = time_fn(lambda *_: gf(q, k, v), None, iters=5, warmup=2)
            times[(name, "bwd")] = t_b
            csv_row(f"attn_{name}_fwdbwd_g{g}s{s}", t_b,
                    f"peak_attn_bytes={peak}")
        ratio = times[("fused", "bwd")] / times[("materialized", "bwd")]
        backend = jax.default_backend()
        csv_row(f"attn_fused_vs_materialized_fwdbwd_g{g}s{s}", 0,
                f"ratio={ratio:.2f}x (backend={backend})")

        # Backward-leg A/B: active fused backward vs pinned jnp KV-scan.
        # The scan variant scopes use_plan around the op call, so the
        # backward-leg choice is baked into that trace only — the active
        # variant's jit wrapper is untouched (no global to leak).
        def active_loss(q_, k_, v_):
            return jnp.sum(ops.fused_attention(
                q_, k_, v_, bias=bias, mask=mask, kv_tile=KV_TILE) ** 2)

        scan_plan = _scan_bwd_plan()

        def scan_loss(q_, k_, v_):
            with use_plan(scan_plan):
                return jnp.sum(ops.fused_attention(
                    q_, k_, v_, bias=bias, mask=mask, kv_tile=KV_TILE) ** 2)

        f_active = jax.jit(jax.grad(active_loss, argnums=(0, 1, 2)))
        t_active = time_fn(lambda *_: f_active(q, k, v), None, iters=5,
                           warmup=2)
        f_scan = jax.jit(jax.grad(scan_loss, argnums=(0, 1, 2)))
        t_scan = time_fn(lambda *_: f_scan(q, k, v), None, iters=5,
                         warmup=2)
        active_leg = ("pallas" if ops._use_pallas(ops.kernel_leg("attention"))
                      else "jnp-scan")
        csv_row(f"attn_bwd_active_g{g}s{s}", t_active, f"leg={active_leg}")
        csv_row(f"attn_bwd_scan_g{g}s{s}", t_scan, "leg=jnp-scan")
        csv_row(f"attn_bwd_active_vs_scan_g{g}s{s}", 0,
                f"ratio={t_active / t_scan:.2f}x (backend={backend})")


if __name__ == "__main__":
    run()
