from repro.optim.optimizers import (  # noqa: F401
    OptState,
    adamw_init,
    adamw_update,
    lamb_init,
    lamb_update,
    clip_by_global_norm,
    make_optimizer,
)
from repro.optim.schedules import cosine_schedule, linear_warmup  # noqa: F401
