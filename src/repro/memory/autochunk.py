"""AutoChunk: an activation-memory planner for the chunk knobs.

FastFold's AutoChunk "automatically determines the chunk strategy" instead of
hand-tuned constants. This module is that planner for our stack: given the
static tensor shapes of a forward pass, the compute dtype, and the per-chip
HBM budget (``launch.mesh.HBM_BYTES``), it picks

  * ``inference_chunk`` — paper-§V.C group chunking of the attention sites,
  * ``opm_chunk``       — Outer-Product-Mean j-chunking (materialized path),
  * ``attn_kv_tile``    — KV tile of the fused flash-attention kernel
                          (forward tile and backward recompute block),
  * ``tri_k_tile``      — tile of the fused triangle-mult kernel (Pallas k
                          accumulation tile / XLA j block / bwd recompute),
  * ``opm_s_tile``      — tile of the fused outer-product-mean kernel
                          (Pallas s tile / XLA j block / bwd recompute),

as the LEAST-chunked settings whose modeled peak activation bytes fit the
budget (0 = knob off / kernel default — selected whenever the unchunked plan
fits). Chunk knobs serialize compute, so the preference order when shrinking
is: kernel tiles first (near-free: still one sweep over the data — KV tile,
then triangle/OPM tiles), then OPM j-chunk (scan), then inference_chunk
(whole attention sites serialized).

Contract:
  * Planning is pure Python over static shapes — it runs at trace time
    (``alphafold_forward``), never inside the computation.
  * The returned plan never exceeds the budget when ANY candidate fits;
    ``fits=False`` flags that even the smallest plan is over budget (the
    caller decides — e.g. raise the DAP degree, paper Table V).
  * Hand-set (nonzero) knobs are respected: they are pinned during planning
    and never overwritten by ``resolve_evoformer_config``.

The memory model is the roofline-style dominant-term model used by
``bench_inference`` (paper §III.B: the cubic N_r^3*H attention transient),
not a byte-exact simulator: every term is the size of one live dominant
buffer, and the total is the peak of the block's phases.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp

from repro.kernels.ops import (
    _DEFAULT_KV_TILE,
    _DEFAULT_OPM_TILE,
    _DEFAULT_TRI_TILE,
)
from repro.launch.mesh import HBM_BYTES


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _eff_chunk(total: int, chunk: int) -> int:
    """Effective processed-at-once extent for a tile knob (0 = whole). Tiles
    (the attention KV tile) need no divisibility — the kernel pads + masks."""
    if chunk and 0 < chunk < total:
        return chunk
    return total


def _eff_div_chunk(total: int, chunk: int) -> int:
    """Effective extent for a CHUNK knob. Mirrors the runtime exactly:
    ``_gated_attention`` and ``outer_product_mean`` silently run UNCHUNKED
    when the chunk does not divide the extent (``g % chunk != 0``), so a
    non-dividing chunk must be modeled as the whole extent — otherwise a
    plan could claim fits=True and then run unchunked over budget."""
    if chunk and 0 < chunk < total and total % chunk == 0:
        return chunk
    return total


# ---------------------------------------------------------------------------
# Memory model
# ---------------------------------------------------------------------------


def attention_transient_bytes(
    groups: int,
    heads: int,
    seq: int,
    head_dim: int,
    *,
    kv_len: int | None = None,
    kv_tile: int = 0,
    fused: bool = True,
    dtype_bytes: int = 2,
) -> int:
    """Peak transient of one gated-attention site over ``groups`` rows.

    fused (flash kernel): q/k/v/out in compute dtype plus the fp32
    (groups, heads, seq, kv_tile) recompute block of the backward scan — the
    largest live buffer on the fused path; it scales with the KV tile, not
    with kv_len^2.

    scores-materialized: two (groups, heads, seq, kv_len) copies
    (scores + probs) — the paper's cubic transient when groups ~ seq.
    """
    kv = kv_len if kv_len is not None else seq
    qkvo = 4 * groups * seq * heads * head_dim * dtype_bytes
    if fused:
        tile = _eff_chunk(kv, kv_tile or _DEFAULT_KV_TILE)
        block = groups * heads * seq * tile * 4          # fp32 p/ds block
        lse = groups * heads * seq * 4
        return qkvo + block + lse
    return qkvo + 2 * groups * heads * seq * kv * dtype_bytes


def triangle_transient_bytes(
    rows_loc: int,
    n_res: int,
    c_mult: int,
    *,
    tile: int = 0,
    fused: bool = True,
    dtype_bytes: int = 2,
) -> int:
    """Peak transient of one triangular multiplicative update over
    ``rows_loc`` local pair rows.

    fused (ops.fused_triangle_mult): the merged a/gate projections plus the
    gathered (r, k, c) right operand in compute dtype, plus the fp32
    j-block product of the kernel's sweep / the backward's recompute scan —
    bounded by the tile, not by r.

    materialized: same operands plus the full (rows_loc, r, c) fp32 product
    the LayerNorm reads.
    """
    operands = c_mult * dtype_bytes * (4 * rows_loc * n_res
                                       + n_res * n_res)
    if fused:
        blk = _eff_chunk(n_res, tile or _DEFAULT_TRI_TILE)
        return operands + rows_loc * blk * c_mult * 4
    return operands + rows_loc * n_res * c_mult * 4


def opm_transient_bytes(
    rows_loc: int,
    n_res: int,
    n_seq: int,
    c_opm: int,
    *,
    tile: int = 0,
    opm_chunk: int = 0,
    fused: bool = True,
    dtype_bytes: int = 2,
) -> int:
    """Peak transient of the Outer-Product-Mean over ``rows_loc`` local pair
    rows: the gathered right projection plus the fp32 (rows_loc, j, c, c)
    outer-product block — j bounded by the fused op's tile (s/j sweep) or,
    on the materialized path, by the opm_chunk scan (full r when off)."""
    gathered = n_seq * n_res * c_opm * dtype_bytes
    if fused:
        jc = _eff_chunk(n_res, tile or _DEFAULT_OPM_TILE)
    else:
        jc = _eff_div_chunk(n_res, opm_chunk)
    return gathered + rows_loc * jc * c_opm * c_opm * 4


def evoformer_peak_bytes(
    cfg,
    *,
    batch: int,
    n_seq: int,
    n_res: int,
    dap: int = 1,
    fused: bool = True,
    inference_chunk: int = 0,
    opm_chunk: int = 0,
    attn_kv_tile: int = 0,
    tri_k_tile: int = 0,
    opm_s_tile: int = 0,
) -> dict:
    """Dominant per-device activation terms (bytes) of one Evoformer block.

    cfg: EvoformerConfig (duck-typed: d_msa, d_pair, msa_heads, pair_heads,
    head_dim, opm_dim, tri_mult_dim, compute_dtype). Returns a dict of named
    terms; ``sum(values())`` is the modeled peak.
    """
    dt = jnp.dtype(cfg.compute_dtype).itemsize
    s_loc = _ceil_div(n_seq, dap)
    r_loc = _ceil_div(n_res, dap)

    terms = {
        # A few live copies of each representation (input, LN'ed, update).
        "msa_rep": 3 * batch * s_loc * n_res * cfg.d_msa * dt,
        "pair_rep": 3 * batch * r_loc * n_res * cfg.d_pair * dt,
        # Gathered (B, H, r, r) pair-bias tensors — not chunkable.
        "pair_bias": batch * max(cfg.msa_heads, cfg.pair_heads)
        * n_res * n_res * dt,
        # Triangular mult: projections + gathered operand + the product
        # block (fp32 full row when materialized, tile-bounded when fused).
        "tri_mult": batch * triangle_transient_bytes(
            r_loc, n_res, cfg.tri_mult_dim, tile=tri_k_tile, fused=fused,
            dtype_bytes=dt),
    }
    # Attention: MSA row (groups = local MSA rows) and triangle (groups =
    # local pair rows) phases don't overlap — take the max.
    attn_row = attention_transient_bytes(
        batch * _eff_div_chunk(s_loc, inference_chunk), cfg.msa_heads, n_res,
        cfg.head_dim, kv_tile=attn_kv_tile, fused=fused, dtype_bytes=dt)
    attn_tri = attention_transient_bytes(
        batch * _eff_div_chunk(r_loc, inference_chunk), cfg.pair_heads, n_res,
        cfg.head_dim, kv_tile=attn_kv_tile, fused=fused, dtype_bytes=dt)
    terms["attention"] = max(attn_row, attn_tri)
    # Outer Product Mean: gathered right projection + the fp32 outer-product
    # block (opm_s_tile-bounded when fused, opm_chunk scan otherwise).
    terms["opm"] = batch * opm_transient_bytes(
        r_loc, n_res, n_seq, cfg.opm_dim, tile=opm_s_tile,
        opm_chunk=opm_chunk, fused=fused, dtype_bytes=dt)
    return terms


def modeled_evoformer_peak(
    cfg,
    *,
    batch: int,
    n_seq: int,
    n_res: int,
    dap: int = 1,
    fused: bool = True,
) -> int:
    """Total modeled peak (sum of ``evoformer_peak_bytes`` terms) with the
    cfg's OWN chunk/tile knobs — the single number the ``PeakBytesWithin``
    contract (repro/analysis) cross-validates against what XLA's
    ``memory_analysis()`` says the compiled program actually allocates."""
    return sum(evoformer_peak_bytes(
        cfg, batch=batch, n_seq=n_seq, n_res=n_res, dap=dap, fused=fused,
        inference_chunk=cfg.inference_chunk, opm_chunk=cfg.opm_chunk,
        attn_kv_tile=getattr(cfg, "attn_kv_tile", 0),
        tri_k_tile=getattr(cfg, "tri_k_tile", 0),
        opm_s_tile=getattr(cfg, "opm_s_tile", 0)).values())


# ---------------------------------------------------------------------------
# Evoformer planner
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ChunkPlan:
    inference_chunk: int = 0
    opm_chunk: int = 0
    attn_kv_tile: int = 0
    est_bytes: int = 0
    budget_bytes: int = 0
    fits: bool = True
    # Appended fields (keep positional compatibility with older callers):
    # tiles of the fused triangle-mult / outer-product-mean kernels
    # (0 = kernel default — already tile-bounded).
    tri_k_tile: int = 0
    opm_s_tile: int = 0

    def describe(self) -> str:
        return (f"ic={self.inference_chunk} oc={self.opm_chunk} "
                f"kt={self.attn_kv_tile} tt={self.tri_k_tile} "
                f"ot={self.opm_s_tile} est={self.est_bytes >> 20}MB "
                f"budget={self.budget_bytes >> 20}MB fits={self.fits}")


_IC_CANDIDATES = (0, 256, 128, 64, 32, 16, 8, 4, 2, 1)
_OC_CANDIDATES = (0, 1024, 512, 256, 128, 64, 32, 16, 8)
_KT_CANDIDATES = (0, 256, 128)
_TT_CANDIDATES = (0, 64, 32, 16)    # triangle tile below its default 128
_OT_CANDIDATES = (0, 64, 32, 16)    # OPM tile below its default 128


def _knob_candidates(fixed: int, options, limit: int):
    if fixed:
        return (fixed,)
    return tuple(o for o in options if o == 0 or o < limit) or (0,)


def _div_candidates(fixed: int, options, *totals):
    """Candidates for a CHUNK knob: 0 (off) plus values that divide at least
    one of the chunked extents (non-dividing chunks are runtime no-ops — see
    _eff_div_chunk), augmented with total/k divisors so non-power-of-two
    extents still get effective options."""
    if fixed:
        return (fixed,)
    cands: set[int] = set()
    for total in totals:
        cands |= {o for o in options if 0 < o < total and total % o == 0}
        cands |= {total // k for k in (2, 4, 8, 16, 32, 64)
                  if total % k == 0 and 1 <= total // k < total}
    return (0,) + tuple(sorted(cands, reverse=True))


def plan_evoformer_chunks(
    cfg,
    *,
    batch: int,
    n_seq: int,
    n_res: int,
    budget_bytes: int = HBM_BYTES,
    dap: int = 1,
    fused: bool = True,
) -> ChunkPlan:
    """Pick the least-chunked (inference_chunk, opm_chunk, attn_kv_tile)
    whose modeled peak fits ``budget_bytes``. Nonzero knobs already set on
    ``cfg`` are pinned. Never exceeds the budget when any candidate fits;
    otherwise returns the minimal-memory plan with ``fits=False``."""
    s_loc = _ceil_div(n_seq, dap)
    r_loc = _ceil_div(n_res, dap)
    groups = max(s_loc, r_loc)
    ics = _div_candidates(cfg.inference_chunk, _IC_CANDIDATES, s_loc, r_loc)
    ocs = _div_candidates(cfg.opm_chunk, _OC_CANDIDATES, n_res)
    kts = _knob_candidates(getattr(cfg, "attn_kv_tile", 0), _KT_CANDIDATES,
                           n_res if fused else 1)
    lim = n_res if fused else 1
    tts = _knob_candidates(getattr(cfg, "tri_k_tile", 0), _TT_CANDIDATES, lim)
    ots = _knob_candidates(getattr(cfg, "opm_s_tile", 0), _OT_CANDIDATES, lim)

    def est(ic, oc, kt, tt, ot) -> int:
        return sum(evoformer_peak_bytes(
            cfg, batch=batch, n_seq=n_seq, n_res=n_res, dap=dap, fused=fused,
            inference_chunk=ic, opm_chunk=oc, attn_kv_tile=kt,
            tri_k_tile=tt, opm_s_tile=ot).values())

    def serialization_cost(ic, oc, kt, tt, ot):
        # Lexicographic preference: avoid/maximize inference_chunk first
        # (whole sites serialized), then opm_chunk (scan), then the kernel
        # tiles (near-free: still one sweep each).
        return (
            _ceil_div(groups, ic) if ic else 0,
            _ceil_div(n_res, oc) if oc else 0,
            _ceil_div(n_res, kt) if kt else 0,
            _ceil_div(n_res, tt) if tt else 0,
            _ceil_div(n_res, ot) if ot else 0,
        )

    best = None          # least serialization among fitting plans
    smallest = None      # minimal est_bytes overall (fallback)
    for ic in ics:
        for oc in ocs:
            for kt in kts:
                for tt in tts:
                    for ot in ots:
                        e = est(ic, oc, kt, tt, ot)
                        key = serialization_cost(ic, oc, kt, tt, ot)
                        if smallest is None or e < smallest[0]:
                            smallest = (e, ic, oc, kt, tt, ot)
                        if e <= budget_bytes and (best is None
                                                  or key < best[0]):
                            best = (key, e, ic, oc, kt, tt, ot)
    if best is not None:
        _, e, ic, oc, kt, tt, ot = best
        return ChunkPlan(ic, oc, kt, e, budget_bytes, True, tt, ot)
    e, ic, oc, kt, tt, ot = smallest
    return ChunkPlan(ic, oc, kt, e, budget_bytes, False, tt, ot)


def apply_plan(cfg, plan: ChunkPlan):
    """EvoformerConfig with the plan's knobs filled in (hand-set nonzero
    knobs on cfg win — the planner already pinned them)."""
    return dataclasses.replace(
        cfg,
        inference_chunk=cfg.inference_chunk or plan.inference_chunk,
        opm_chunk=cfg.opm_chunk or plan.opm_chunk,
        attn_kv_tile=cfg.attn_kv_tile or plan.attn_kv_tile,
        tri_k_tile=getattr(cfg, "tri_k_tile", 0) or plan.tri_k_tile,
        opm_s_tile=getattr(cfg, "opm_s_tile", 0) or plan.opm_s_tile,
    )


def resolve_evoformer_config(
    cfg,
    *,
    batch: int,
    n_seq: int,
    n_res: int,
    dap: int = 1,
    budget_bytes: int | None = None,
):
    """AutoChunk entry point used by ``alphafold_forward``: returns cfg with
    every knob left at 0 replaced by the planned value (no-op when
    ``cfg.auto_chunk`` is False or everything already fits unchunked).
    ``budget_bytes=None`` resolves the current ExecutionPlan's
    MemoryPolicy.hbm_budget, falling back to the hardware HBM_BYTES."""
    if not getattr(cfg, "auto_chunk", False):
        return cfg
    if budget_bytes is None:
        from repro.exec.plan import current_plan

        budget_bytes = current_plan().memory.hbm_budget or HBM_BYTES
    from repro.kernels import ops

    fused = ops.fused_attention_supported(
        (batch, n_seq, n_res, cfg.msa_heads, cfg.head_dim), kv_len=n_res,
        dtype=cfg.compute_dtype)
    plan = plan_evoformer_chunks(
        cfg, batch=batch, n_seq=n_seq, n_res=n_res,
        budget_bytes=budget_bytes, dap=dap, fused=fused)
    return apply_plan(cfg, plan)


# ---------------------------------------------------------------------------
# Decoder / serving planner
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DecoderPlan:
    attn_q_block: int
    attn_kv_block: int
    est_bytes: int
    budget_bytes: int
    fits: bool


def decoder_attention_bytes(cfg, *, n_slots: int, max_seq: int,
                            q_block: int, kv_block: int,
                            seq_len: int | None = None) -> int:
    """Dominant serving-time bytes: the batched KV cache + the prefill
    flash-attention probs block + logits. cfg is a ModelConfig.
    ``seq_len`` bounds the prefill-phase terms to the actual prompt length
    (admission queries); None models the worst case (= max_seq)."""
    hd = cfg.resolved_head_dim
    dt = 1 if getattr(cfg, "kv_cache_int8", False) else 2
    s = min(seq_len or max_seq, max_seq)
    cache = cfg.n_layers * n_slots * max_seq * 2 * cfg.n_kv * hd * dt
    qb = min(q_block or s, s)
    kvb = min(kv_block or s, s)
    probs = cfg.n_heads * qb * kvb * 4              # fp32 block in the scan
    acts = 3 * s * cfg.n_heads * hd * 2
    logits = n_slots * cfg.vocab * 4
    return cache + probs + acts + logits


@dataclass(frozen=True)
class AdmissionCheck:
    """Result of a serving-engine admission query (see
    ``check_decoder_admission``)."""

    fits: bool
    est_bytes: int
    budget_bytes: int
    seq_len: int

    def describe(self) -> str:
        return (f"seq_len={self.seq_len} est={self.est_bytes >> 20}MB "
                f"budget={self.budget_bytes >> 20}MB fits={self.fits}")


_MIN_BLOCK = 32   # the most-shrunk attention block plan_decoder_blocks tries


def check_decoder_admission(cfg, *, n_slots: int, max_seq: int,
                            seq_len: int | None = None,
                            budget_bytes: int = HBM_BYTES) -> AdmissionCheck:
    """Admission query for the serving engine: can a request of
    ``seq_len`` tokens run in an engine of (n_slots, max_seq) within
    ``budget_bytes``? The engine can always degrade its attention blocks
    (but not the KV-cache extent), so a request is admissible iff even the
    most-shrunk block plan fits its plan's budget. Pure Python over static
    shapes — safe to call per submit()."""
    s = min(seq_len or max_seq, max_seq)
    est = decoder_attention_bytes(
        cfg, n_slots=n_slots, max_seq=max_seq,
        q_block=min(_MIN_BLOCK, s), kv_block=min(_MIN_BLOCK, s),
        seq_len=s)
    return AdmissionCheck(est <= budget_bytes, est, budget_bytes, s)


def plan_decoder_blocks(cfg, *, n_slots: int, max_seq: int,
                        budget_bytes: int = HBM_BYTES):
    """Serving-engine AutoChunk: keep the configured attention blocks when
    they fit the HBM budget, otherwise shrink — KV block first, then the q
    block. Returns (ModelConfig, DecoderPlan)."""
    q_opts = [cfg.attn_q_block] + [b for b in (256, 128, 64, 32)
                                   if not cfg.attn_q_block
                                   or b < cfg.attn_q_block]
    kv_opts = [cfg.attn_kv_block] + [b for b in (512, 256, 128, 64, 32)
                                     if not cfg.attn_kv_block
                                     or b < cfg.attn_kv_block]
    best = None
    for qb in q_opts:              # outer: shrink q last
        for kvb in kv_opts:        # inner: shrink kv first
            e = decoder_attention_bytes(cfg, n_slots=n_slots,
                                        max_seq=max_seq, q_block=qb,
                                        kv_block=kvb)
            if best is None or e < best[0]:
                best = (e, qb, kvb)
            if e <= budget_bytes:
                plan = DecoderPlan(qb, kvb, e, budget_bytes, fits=True)
                return dataclasses.replace(
                    cfg, attn_q_block=qb, attn_kv_block=kvb), plan
    e, qb, kvb = best
    plan = DecoderPlan(qb, kvb, e, budget_bytes, fits=False)
    return dataclasses.replace(cfg, attn_q_block=qb, attn_kv_block=kvb), plan
