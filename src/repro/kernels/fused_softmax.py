"""Fused scale+bias+mask+softmax Pallas TPU kernel (paper §IV.A.2, Fig. 5).

GPU→TPU adaptation: the paper assigns one *warp* per (short) softmax row and
reduces with ``__shfl_xor_sync``. TPUs have no warps; the equivalent strategy is
to pack a tile of rows into VMEM — block shape ``(1, 1, ROW_TILE, C_pad)``,
8x128-aligned — and let the VPU do the lane reduction over the last axis. The
fusion benefit is identical to the paper's: scale, pair-bias add, mask add,
max-subtract, exp, and normalize all happen in a single HBM round trip instead
of five.

Numerical behaviour matches ref.softmax_ref: fp32 accumulation, max-shifted exp.
Out-of-envelope shapes fall back to the oracle in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8
LANE = 128


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _softmax_kernel(*refs, scale: float, c_actual: int, has_bias: bool, has_mask: bool):
    idx = 0
    x_ref = refs[idx]; idx += 1
    b_ref = refs[idx] if has_bias else None
    idx += int(has_bias)
    m_ref = refs[idx] if has_mask else None
    idx += int(has_mask)
    o_ref = refs[idx]

    x = x_ref[0, 0].astype(jnp.float32) * scale  # (ROW_TILE, C_pad)
    if b_ref is not None:
        x = x + b_ref[0, 0].astype(jnp.float32)
    if m_ref is not None:
        x = x + m_ref[0].astype(jnp.float32)[None, :]
    # Neutralize lane padding (C_pad > C): padded lanes must not win the max
    # nor contribute to the sum.
    if c_actual != x.shape[-1]:
        lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
        x = jnp.where(lane < c_actual, x, -jnp.inf)
    x_max = jnp.max(x, axis=-1, keepdims=True)
    # Guard fully-masked rows (all -inf): exp(-inf - -inf) would be NaN.
    x_max = jnp.where(jnp.isfinite(x_max), x_max, 0.0)
    ex = jnp.exp(x - x_max)
    denom = jnp.sum(ex, axis=-1, keepdims=True)
    o_ref[0, 0] = (ex / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "has_bias", "has_mask", "interpret")
)
def fused_softmax_pallas(
    x: jax.Array,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
    *,
    scale: float = 1.0,
    has_bias: bool = False,
    has_mask: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """x: (N, H, R, C); bias: (H, R, C) | None; mask: (N, C) | None."""
    n, h, r, c = x.shape
    c_pad = _pad_to(c, LANE)
    row_tile = ROW_TILE if r >= ROW_TILE else r
    grid = (n, h, pl.cdiv(r, row_tile))

    in_specs = [
        pl.BlockSpec((1, 1, row_tile, c_pad), lambda i, j, k: (i, j, k, 0)),
    ]
    operands = [x]
    if has_bias:
        assert bias is not None and bias.ndim == 4 and bias.shape[1:] == (h, r, c)
        rep = n // bias.shape[0]  # rows of x sharing one bias batch element
        in_specs.append(
            pl.BlockSpec((1, 1, row_tile, c_pad),
                         lambda i, j, k: (i // rep, j, k, 0))
        )
        operands.append(bias)
    if has_mask:
        assert mask is not None and mask.shape == (n, c)
        in_specs.append(pl.BlockSpec((1, c_pad), lambda i, j, k: (i, 0)))
        operands.append(mask)

    kernel = functools.partial(
        _softmax_kernel,
        scale=scale,
        c_actual=c,
        has_bias=has_bias,
        has_mask=has_mask,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, row_tile, c_pad), lambda i, j, k: (i, j, k, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(*operands)
