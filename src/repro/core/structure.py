"""Structure module: Invariant Point Attention + backbone frame updates.

Faithful-but-reduced AlphaFold structure module: 8 shared-weight iterations of
IPA (scalar + point + pair attention terms), residue-frame composition via
quaternion updates, and per-iteration backbone outputs for the auxiliary FAPE
loss. The paper (FastFold) optimizes the Evoformer and leaves this module
untouched; it is <10% of step time, replicated under DAP.

Frames are (rotation (..., 3, 3), translation (..., 3)) acting as x -> Rx + t.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.layers.norms import init_layer_norm, layer_norm
from repro.layers.params import Params, dense, init_dense


@dataclass(frozen=True)
class StructureConfig:
    c_s: int = 384          # single representation
    c_z: int = 128          # pair representation
    n_heads: int = 12
    c_hidden: int = 16      # scalar head dim
    n_qk_points: int = 4
    n_v_points: int = 8
    n_iterations: int = 8
    trans_scale: float = 10.0  # nm-scale translations (AlphaFold convention)


# --- rigid-frame utilities --------------------------------------------------

def identity_frames(shape) -> tuple[jax.Array, jax.Array]:
    rot = jnp.broadcast_to(jnp.eye(3, dtype=jnp.float32), shape + (3, 3))
    trans = jnp.zeros(shape + (3,), jnp.float32)
    return rot, trans


def frames_apply(rot, trans, x):
    """x: (..., P, 3) points in local coords -> global."""
    return jnp.einsum("...ij,...pj->...pi", rot, x) + trans[..., None, :]


def frames_invert_apply(rot, trans, x):
    return jnp.einsum("...ji,...pj->...pi", rot, x - trans[..., None, :])


def quat_to_rot(q):
    """Unnormalized quaternion (..., 4) -> rotation matrix (..., 3, 3)."""
    q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-8)
    w, x, y, z = q[..., 0], q[..., 1], q[..., 2], q[..., 3]
    return jnp.stack(
        [
            jnp.stack([1 - 2 * (y**2 + z**2), 2 * (x * y - w * z), 2 * (x * z + w * y)], -1),
            jnp.stack([2 * (x * y + w * z), 1 - 2 * (x**2 + z**2), 2 * (y * z - w * x)], -1),
            jnp.stack([2 * (x * z - w * y), 2 * (y * z + w * x), 1 - 2 * (x**2 + y**2)], -1),
        ],
        axis=-2,
    )


def compose_frames(rot1, trans1, rot2, trans2):
    """(R1,t1) ∘ (R2,t2): first apply 2, then 1."""
    rot = jnp.einsum("...ij,...jk->...ik", rot1, rot2)
    trans = jnp.einsum("...ij,...j->...i", rot1, trans2) + trans1
    return rot, trans


def frames_from_3_points(x1, x2, x3):
    """Gram-Schmidt frames from 3 points (AlphaFold Alg. 21): origin x2,
    x3-x2 defines e1. Used to build ground-truth frames from CA traces."""
    v1 = x3 - x2
    v2 = x1 - x2
    e1 = v1 / (jnp.linalg.norm(v1, axis=-1, keepdims=True) + 1e-8)
    u2 = v2 - e1 * jnp.sum(e1 * v2, axis=-1, keepdims=True)
    e2 = u2 / (jnp.linalg.norm(u2, axis=-1, keepdims=True) + 1e-8)
    e3 = jnp.cross(e1, e2)
    rot = jnp.stack([e1, e2, e3], axis=-1)  # columns are the basis
    return rot, x2


# --- IPA --------------------------------------------------------------------

def init_ipa(key, cfg: StructureConfig) -> Params:
    ks = iter(jax.random.split(key, 10))
    h, c = cfg.n_heads, cfg.c_hidden
    qp, vp = cfg.n_qk_points, cfg.n_v_points
    concat_dim = h * c + h * cfg.c_z + h * vp * 4  # scalar + pair + points(3)+norm
    return {
        "q": init_dense(next(ks), cfg.c_s, h * c, bias=False),
        "kv": init_dense(next(ks), cfg.c_s, 2 * h * c, bias=False),
        "q_pts": init_dense(next(ks), cfg.c_s, h * qp * 3, bias=False),
        "kv_pts": init_dense(next(ks), cfg.c_s, h * (qp + vp) * 3, bias=False),
        "bias_z": init_dense(next(ks), cfg.c_z, h, bias=False),
        "head_w": jnp.zeros((h,), jnp.float32),  # softplus(head_w) point weights
        "out": init_dense(next(ks), concat_dim, cfg.c_s, bias=True, zero_init=True),
    }


def ipa(p: Params, s: jax.Array, z: jax.Array, rot, trans, seq_mask,
        cfg: StructureConfig) -> jax.Array:
    """s: (B, r, c_s); z: (B, r, r, c_z); frames (B, r, 3, 3)/(B, r, 3)."""
    b, r, _ = s.shape
    h, c = cfg.n_heads, cfg.c_hidden
    qp, vp = cfg.n_qk_points, cfg.n_v_points

    q = dense(p["q"], s).reshape(b, r, h, c)
    k, v = jnp.split(dense(p["kv"], s).reshape(b, r, h, 2 * c), 2, axis=-1)
    q_pts = dense(p["q_pts"], s).reshape(b, r, h * qp, 3)
    kv_pts = dense(p["kv_pts"], s).reshape(b, r, h * (qp + vp), 3)
    # local -> global points
    q_pts = frames_apply(rot, trans, q_pts).reshape(b, r, h, qp, 3)
    kv_pts = frames_apply(rot, trans, kv_pts)
    k_pts, v_pts = jnp.split(kv_pts.reshape(b, r, h, qp + vp, 3), [qp], axis=-2)

    # scalar term
    logits = jnp.einsum("bihc,bjhc->bhij", q, k) * (1.0 / jnp.sqrt(3 * c))
    # pair bias term
    logits = logits + jnp.einsum("bijh->bhij", dense(p["bias_z"], z)) * (1.0 / jnp.sqrt(3.0))
    # point distance term
    d2 = jnp.sum(
        jnp.square(q_pts[:, :, None] - k_pts[:, None]), axis=-1
    )  # (b, i, j, h, qp)
    gamma = jax.nn.softplus(p["head_w"])  # (h,)
    w_pt = gamma * (1.0 / jnp.sqrt(3.0)) * (9.0 / (2 * qp)) ** 0.5 * 0.5
    logits = logits - jnp.einsum("bijhp,h->bhij", d2, w_pt)
    logits = jnp.where(seq_mask[:, None, None, :] > 0, logits, -1e9)
    attn = jax.nn.softmax(logits, axis=-1)  # (b, h, i, j)

    o_scalar = jnp.einsum("bhij,bjhc->bihc", attn, v).reshape(b, r, h * c)
    o_pair = jnp.einsum("bhij,bijc->bihc", attn, z).reshape(b, r, h * cfg.c_z)
    o_pts = jnp.einsum("bhij,bjhpx->bihpx", attn, v_pts)  # global coords
    o_pts_local = frames_invert_apply(rot, trans, o_pts.reshape(b, r, h * vp, 3))
    o_pts_norm = jnp.linalg.norm(o_pts_local + 1e-8, axis=-1, keepdims=True)
    o_pts_feat = jnp.concatenate([o_pts_local, o_pts_norm], axis=-1).reshape(b, r, h * vp * 4)

    o = jnp.concatenate([o_scalar, o_pair, o_pts_feat], axis=-1)
    return dense(p["out"], o)


# --- structure module -------------------------------------------------------

def init_structure_module(key, cfg: StructureConfig) -> Params:
    ks = iter(jax.random.split(key, 8))
    return {
        "ln_s": init_layer_norm(cfg.c_s),
        "ln_z": init_layer_norm(cfg.c_z),
        "proj_s": init_dense(next(ks), cfg.c_s, cfg.c_s, bias=False),
        "ipa": init_ipa(next(ks), cfg),
        "ln_ipa": init_layer_norm(cfg.c_s),
        "trans1": init_dense(next(ks), cfg.c_s, cfg.c_s, bias=True),
        "trans2": init_dense(next(ks), cfg.c_s, cfg.c_s, bias=True),
        "trans3": init_dense(next(ks), cfg.c_s, cfg.c_s, bias=True, zero_init=True),
        "ln_trans": init_layer_norm(cfg.c_s),
        "bb_update": init_dense(next(ks), cfg.c_s, 6, bias=True, zero_init=True),
    }


def structure_module(p: Params, s_init: jax.Array, z: jax.Array,
                     seq_mask: jax.Array, cfg: StructureConfig):
    """Returns (final_coords (B, r, 3), traj rot/trans per iteration)."""
    b, r, _ = s_init.shape
    s = dense(p["proj_s"], layer_norm(p["ln_s"], s_init))
    z_n = layer_norm(p["ln_z"], z)
    rot, trans = identity_frames((b, r))

    def body(carry, _):
        s, rot, trans = carry
        s = s + ipa(p["ipa"], s, z_n, rot, trans, seq_mask, cfg)
        s = layer_norm(p["ln_ipa"], s)
        h = jax.nn.relu(dense(p["trans1"], s))
        h = jax.nn.relu(dense(p["trans2"], h))
        s = layer_norm(p["ln_trans"], s + dense(p["trans3"], h))
        upd = dense(p["bb_update"], s)  # (b, r, 6)
        quat = jnp.concatenate(
            [jnp.ones((b, r, 1), upd.dtype), upd[..., :3]], axis=-1
        )
        rot_u = quat_to_rot(quat)
        trans_u = upd[..., 3:] * cfg.trans_scale
        # Frames updated by right-composition with the local update; gradients
        # flow through rotations (no stop-grad: reduced variant trains fine).
        rot, trans = compose_frames(rot, trans, rot_u, trans_u)
        return (s, rot, trans), (rot, trans)

    (s, rot, trans), traj = jax.lax.scan(
        body, (s, rot, trans), None, length=cfg.n_iterations
    )
    return trans, (rot, trans), traj  # CA coords = frame origins
