"""Qwen2-1.5B [arXiv:2407.10671]: dense GQA decoder with QKV bias."""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense", source="arXiv:2407.10671",
    n_layers=28, d_model=1536, n_heads=12, n_kv=2, d_ff=8960, vocab=151936,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
)
REDUCED = reduced(CONFIG)
