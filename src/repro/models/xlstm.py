"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM (matrix memory,
parallelizable) and sLSTM (scalar memory, inherently recurrent).

mLSTM is run in its parallel (quadratic-within-chunk, linear-across-chunks)
formulation for train/prefill and as an O(1)-state recurrence for decode.
sLSTM has recurrent (hidden-to-gate) connections, so train/prefill also scan
— that sequential dependence is exactly why the paper's all_to_all axis-swap
DAP does not apply to this family (DESIGN.md §Arch-applicability); sequence
parallelism here means chunked scans with carry hand-off.

Both blocks use exponential gating with the max-state stabilizer m_t.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.norms import init_rms_norm, rms_norm
from repro.layers.params import Params, init_dense, dense


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, d_model: int, n_heads: int, expand: int = 2) -> Params:
    di = expand * d_model
    ks = iter(jax.random.split(key, 8))
    return {
        "up": init_dense(next(ks), d_model, 2 * di, bias=False),
        "qkv": init_dense(next(ks), di, 3 * di, bias=False),
        "gates": init_dense(next(ks), di, 2 * n_heads, bias=True),
        "norm": init_rms_norm(di),
        "down": init_dense(next(ks), di, d_model, bias=False, zero_init=True),
        "_di": jnp.zeros((0, di)),  # records di for shape inference
    }


def _mlstm_gates(p, x_in, n_heads):
    gi = dense(p["gates"], x_in).astype(jnp.float32)
    log_i, log_f = jnp.split(gi, 2, axis=-1)          # (B, S, H) each
    log_f = -jax.nn.softplus(-log_f)                  # log sigmoid(f)
    return log_i, log_f


def mlstm_forward(p: Params, x: jax.Array, n_heads: int, *, chunk: int = 256,
                  state=None):
    """Chunkwise-parallel mLSTM (train/prefill). x: (B, S, d).

    TPU adaptation: within a chunk the gated linear attention runs as dense
    MXU GEMMs (the parallel form); across chunks the (C, n, m) state is
    carried by a lax.scan — O(S * chunk) memory instead of O(S^2), O(S/chunk)
    sequential depth. This is the mLSTM analogue of the paper's "adapt the
    blocking to the memory hierarchy" kernel story.
    """
    b, s, _ = x.shape
    up = dense(p["up"], x)
    x_in, z = jnp.split(up, 2, axis=-1)
    di = x_in.shape[-1]
    hd = di // n_heads
    qkv = dense(p["qkv"], x_in)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, n_heads, hd).astype(jnp.float32)
    k = k.reshape(b, s, n_heads, hd).astype(jnp.float32) / jnp.sqrt(float(hd))
    v = v.reshape(b, s, n_heads, hd).astype(jnp.float32)
    log_i, log_f = _mlstm_gates(p, x_in, n_heads)     # (B, S, H)

    L = min(chunk, s)
    assert s % L == 0, "sequence length must be a multiple of the chunk size"
    nc = s // L

    def split_chunks(t):  # (B, S, ...) -> (nc, B, L, ...)
        return t.reshape(b, nc, L, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = split_chunks(q), split_chunks(k), split_chunks(v)
    ic, fc = split_chunks(log_i), split_chunks(log_f)

    if state is None:
        state = init_mlstm_state(b, di, n_heads)

    def chunk_step(carry, inp):
        C_p, n_p, m_p = carry["C"], carry["n"], carry["m"]
        q_i, k_i, v_i, li, lf = inp                   # (B, L, H, hd)/(B, L, H)
        bcf = jnp.cumsum(lf, axis=1)                  # inclusive cumsum (B,L,H)
        # intra-chunk decay matrix: t >= j: b_t - b_j + i_j
        log_d = bcf[:, :, None] - bcf[:, None, :] + li[:, None, :, :]
        causal = jnp.tril(jnp.ones((L, L), bool))
        log_d = jnp.where(causal[None, :, :, None], log_d, -jnp.inf)
        intra_max = jnp.max(log_d, axis=2)            # (B, L, H)
        # inter-chunk stabilizer: b_t + m_prev
        inter = bcf + m_p[:, None, :]
        m_t = jnp.maximum(intra_max, inter)           # (B, L, H)
        d_mat = jnp.exp(log_d - m_t[:, :, None])
        scores = jnp.einsum("bihd,bjhd->bijh", q_i, k_i)
        w = scores * d_mat
        inter_w = jnp.exp(inter - m_t)                # (B, L, H)
        h_intra = jnp.einsum("bijh,bjhd->bihd", w, v_i)
        h_inter = jnp.einsum("bihd,bhde->bihe", q_i, C_p) * inter_w[..., None]
        # normalizer: n_t = sum_j D_tj k_j + inter_w_t * n_prev; den = |n_t.q_t|
        n_vec = jnp.einsum("bijh,bjhd->bihd", d_mat, k_i)
        n_vec = n_vec + inter_w[..., None] * n_p[:, None, :, :]
        den = jnp.abs(jnp.einsum("bihd,bihd->bih", n_vec, q_i))
        den = jnp.maximum(den, jnp.exp(-m_t)) + 1e-6
        h = (h_intra + h_inter) / den[..., None]      # (B, L, H, hd)

        # end-of-chunk state
        b_L = bcf[:, -1:, :]                          # (B, 1, H)
        m_new = jnp.maximum(b_L[:, 0] + m_p, jnp.max(b_L - bcf + li, axis=1))
        w_end = jnp.exp(b_L - bcf + li - m_new[:, None, :])   # (B, L, H)
        C_new = (jnp.exp(b_L[:, 0] + m_p - m_new)[..., None, None] * C_p
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", w_end, k_i, v_i))
        n_new = (jnp.exp(b_L[:, 0] + m_p - m_new)[..., None] * n_p
                 + jnp.einsum("bjh,bjhd->bhd", w_end, k_i))
        return {"C": C_new, "n": n_new, "m": m_new}, h

    state, hs = jax.lax.scan(chunk_step, state, (qc, kc, vc, ic, fc))
    h = hs.swapaxes(0, 1).reshape(b, s, di)           # (B, S, di)
    out = rms_norm(p["norm"], h.astype(x.dtype))
    out = out * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = dense(p["down"], out)
    return out, state


def mlstm_decode(p: Params, x: jax.Array, state, n_heads: int):
    """O(1) recurrent step. x: (B, 1, d)."""
    b = x.shape[0]
    up = dense(p["up"], x)
    x_in, z = jnp.split(up, 2, axis=-1)
    di = x_in.shape[-1]
    hd = di // n_heads
    qkv = dense(p["qkv"], x_in)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, n_heads, hd).astype(jnp.float32)
    k = k.reshape(b, n_heads, hd).astype(jnp.float32) / jnp.sqrt(float(hd))
    v = v.reshape(b, n_heads, hd).astype(jnp.float32)
    log_i, log_f = _mlstm_gates(p, x_in, n_heads)
    log_i, log_f = log_i[:, 0], log_f[:, 0]           # (B, H)

    m_new = jnp.maximum(log_f + state["m"], log_i)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    i_s = jnp.exp(log_i - m_new)
    C = f_s[..., None, None] * state["C"] + i_s[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n = f_s[..., None] * state["n"] + i_s[..., None] * k
    num = jnp.einsum("bhde,bhd->bhe", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                      jnp.exp(-m_new))
    h = (num / (den[..., None] + 1e-6)).reshape(b, 1, di)
    out = rms_norm(p["norm"], h.astype(x.dtype))
    out = out * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return dense(p["down"], out), {"C": C, "n": n, "m": m_new}


def init_mlstm_state(batch: int, d_inner: int, n_heads: int):
    hd = d_inner // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, d_model: int, n_heads: int) -> Params:
    ks = iter(jax.random.split(key, 4))
    return {
        # input projections for gates z, i, f, o (merged GEMM)
        "w": init_dense(next(ks), d_model, 4 * d_model, bias=True),
        # recurrent per-head block-diagonal connections, merged
        "r": init_dense(next(ks), d_model, 4 * d_model, bias=False),
        "norm": init_rms_norm(d_model),
        "down": init_dense(next(ks), d_model, d_model, bias=False,
                           zero_init=True),
    }


def _slstm_step(p, wx_t, state, d):
    """One sLSTM step. wx_t: (B, 4d) precomputed input projection."""
    h, c, n, m = state["h"], state["c"], state["n"], state["m"]
    gates = wx_t + dense(p["r"], h).astype(jnp.float32)
    z, i, f, o = jnp.split(gates, 4, axis=-1)         # (B, d) each
    log_f = -jax.nn.softplus(-f)                      # forget via sigmoid
    m_new = jnp.maximum(log_f + m, i)
    i_s = jnp.exp(i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o) * c_new / (n_new + 1e-6)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def slstm_forward(p: Params, x: jax.Array, state=None):
    """Sequential scan over time. x: (B, S, d)."""
    b, s, d = x.shape
    wx = dense(p["w"], x).astype(jnp.float32)         # (B, S, 4d)
    if state is None:
        state = init_slstm_state(b, d)

    def step(st, wx_t):
        st = _slstm_step(p, wx_t, st, d)
        return st, st["h"]

    state, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)             # (B, S, d)
    out = dense(p["down"], rms_norm(p["norm"], h))
    return out, state


def slstm_decode(p: Params, x: jax.Array, state):
    b, _, d = x.shape
    wx = dense(p["w"], x).astype(jnp.float32)[:, 0]
    state = _slstm_step(p, wx, state, d)
    h = state["h"][:, None].astype(x.dtype)
    return dense(p["down"], rms_norm(p["norm"], h)), state


def init_slstm_state(batch: int, d: int):
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, d), -1e30, jnp.float32)}
