"""Paper Fig. 9 — Fused LayerNorm.

Unfused chain (mean, var, normalize, affine as separate dispatches) vs the
fused kernel, over the paper's (rows, small-hidden) range; plus oracle
equivalence and HBM-traffic model.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.kernels import ops, ref

SIZES = [(4096, 128), (16384, 128), (4096, 256), (16384, 256), (4096, 1024),
         (1024, 8960)]


def run():
    for rows, cols in SIZES:
        x = jax.random.normal(jax.random.PRNGKey(0), (rows, cols),
                              jnp.bfloat16)
        g = jax.random.normal(jax.random.PRNGKey(1), (cols,))
        b = jax.random.normal(jax.random.PRNGKey(2), (cols,))

        mean_f = jax.jit(lambda x: jnp.mean(x.astype(jnp.float32), -1,
                                            keepdims=True))
        var_f = jax.jit(lambda x, m: jnp.mean(
            jnp.square(x.astype(jnp.float32) - m), -1, keepdims=True))
        norm_f = jax.jit(lambda x, m, v: (x.astype(jnp.float32) - m)
                         * jax.lax.rsqrt(v + 1e-5))
        affine_f = jax.jit(lambda y, g, b: (y * g + b).astype(jnp.bfloat16))

        def unfused(x, g, b):
            m = mean_f(x)
            v = var_f(x, m)
            return affine_f(norm_f(x, m, v), g, b)

        # CPU stand-in for the fused kernel (see bench_softmax note): single
        # dispatch, XLA-fused; the Pallas kernel is verified by allclose.
        fused = jax.jit(lambda x, g, b: ref.layer_norm_ref(x, g, b))

        got_kernel = ops.layer_norm(x, g, b)
        want = ref.layer_norm_ref(x, g, b)
        np.testing.assert_allclose(np.asarray(got_kernel, np.float32),
                                   np.asarray(want, np.float32), atol=3e-2)

        t_un = time_fn(unfused, x, g, b, iters=10)
        t_fu = time_fn(fused, x, g, b, iters=10)
        csv_row(f"layernorm_{rows}x{cols}_unfused", t_un, "4 dispatches")
        csv_row(f"layernorm_{rows}x{cols}_fused", t_fu,
                f"speedup={t_un / t_fu:.2f}x pallas_kernel_allclose=ok")


if __name__ == "__main__":
    run()
