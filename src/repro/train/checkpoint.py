"""Checkpointing: pytree <-> .npz with path-flattened keys + JSON metadata.

Atomic (tmp + rename), keeps the last `keep` checkpoints, restores into the
example tree's structure/dtypes (so bf16 params round-trip exactly).
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        # npz has no bf16/f8 codecs: store exotic float dtypes as f32
        # (bf16 -> f32 -> bf16 round-trips exactly); restore casts back.
        if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16",
                                                       "float8_e4m3fn",
                                                       "float8_e5m2"):
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3,
                    metadata: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    meta = {"step": step}
    meta.update(metadata or {})
    with open(path + ".json", "w") as f:
        json.dump(meta, f)
    _gc(directory, keep)
    return path


def _gc(directory: str, keep: int):
    ckpts = sorted(
        f for f in os.listdir(directory)
        if re.fullmatch(r"ckpt_\d+\.npz", f)
    )
    for old in ckpts[:-keep]:
        os.remove(os.path.join(directory, old))
        meta = os.path.join(directory, old + ".json")
        if os.path.exists(meta):
            os.remove(meta)


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(
        f for f in os.listdir(directory)
        if re.fullmatch(r"ckpt_\d+\.npz", f)
    )
    return os.path.join(directory, ckpts[-1]) if ckpts else None


def restore_checkpoint(path: str, example_tree):
    """Restore into example_tree's structure, casting to its leaf dtypes."""
    data = np.load(path)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
    new_leaves = []
    for kpath, leaf in leaves_p:
        key = "/".join(_path_str(p) for p in kpath)
        arr = data[key]
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
