"""Dynamic Axial Parallelism drivers (paper §IV.B).

``dap_shard_map(fn, mesh)`` wraps an Evoformer computation written against the
Dist interface so it runs with *explicit* collectives over the ``model`` mesh
axis — the paper-faithful path. Inputs/outputs use the DAP sharding
convention:

  msa      (B, s, r, Hm) sharded P(batch_axes, 'model', None, None)
  pair     (B, i, j, Hz) sharded P(batch_axes, 'model', None, None)
  msa_mask like msa; pair_mask_loc like pair; seq_mask replicated over model.
  params   replicated over 'model' (DAP's defining property: full parameters
           per device, sharded activations).

Inside the shard_map body every tensor is a local shard, so the Evoformer's
four attention sites run the fused flash-attention kernel directly on their
local (B, G/N, S, H, D) blocks (ShardMapDist.sharded_attention) — the
paper-faithful DAP path composes with the §IV.A kernels with no resharding.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.dist import ShardMapDist, batch_spec, shard_map_compat
from repro.core import evoformer as evo


def dap_specs(mesh):
    b = batch_spec(mesh)
    seq = P(b, "model", None, None)
    mask3 = P(b, "model", None)
    return {
        "msa": seq,
        "pair": seq,
        "msa_mask": mask3,
        "seq_mask": P(b, None),
        "pair_mask": mask3,
    }


def shard_dap_inputs(mesh, msa, pair, msa_mask, seq_mask, pair_mask):
    """Place global arrays with the DAP sharding (host -> devices)."""
    s = dap_specs(mesh)
    put = lambda x, spec: jax.device_put(x, NamedSharding(mesh, spec))
    return (
        put(msa, s["msa"]),
        put(pair, s["pair"]),
        put(msa_mask, s["msa_mask"]),
        put(seq_mask, s["seq_mask"]),
        put(pair_mask, s["pair_mask"]),
    )


def dap_evoformer_stack(mesh, cfg: evo.EvoformerConfig, *, train: bool = False,
                        remat: bool = True):
    """Returns a jit-able fn(params, msa, pair, msa_mask, seq_mask, pair_mask,
    rng?) running the full Evoformer stack under paper-faithful DAP."""
    s = dap_specs(mesh)
    dist = ShardMapDist(axis="model")

    def local_fn(params, msa, pair, msa_mask, seq_mask, pair_mask):
        return evo.evoformer_stack(
            params, msa, pair, msa_mask, seq_mask, pair_mask,
            dist=dist, cfg=cfg, rng=None, train=train, remat=remat,
        )

    return shard_map_compat(
        local_fn,
        mesh,
        (P(), s["msa"], s["pair"], s["msa_mask"], s["seq_mask"],
         s["pair_mask"]),
        (s["msa"], s["pair"]),
    )
