"""Execution-policy package: the ExecutionPlan object, plan scoping, env
compatibility, and the FastFold facade (see plan.py for the policy matrix).

Importing this package (or repro.exec.plan / repro.exec.envcompat) never
imports jax — launchers use ``envcompat.force_host_device_count`` before
first jax init. ``FastFold`` (which does need jax) is re-exported lazily.
"""
from repro.exec.plan import (  # noqa: F401
    AsyncPolicy,
    ExecutionPlan,
    KernelPolicy,
    MemoryPolicy,
    ParallelPolicy,
    PRESETS,
    current_plan,
    preset,
    use_plan,
)


def __getattr__(name):
    if name == "FastFold":
        from repro.exec.session import FastFold

        return FastFold
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
