#!/usr/bin/env bash
# Tier-1 CI, eight legs — each test leg is a named ExecutionPlan preset selected
# through the single REPRO_PLAN entry point (resolved by the one env-compat
# module, src/repro/exec/envcompat.py -> repro.exec.plan.PRESETS):
#   1. default          — KernelPolicy(enabled=True): Pallas kernels on TPU;
#                         on CPU each op runs its XLA-native leg (fused
#                         attention = online-softmax scan, fused
#                         triangle/OPM = j-block scans).
#   2. oracle           — KernelPolicy(enabled=False): pure-jnp oracles, the
#                         scores-materialized attention, and the
#                         materialized pair-stack paths (A/B legs).
#   3. interpret        — KernelPolicy(interpret=True): the Pallas kernels
#                         (fwd + the fused attention backward + the fused
#                         triangle/OPM forwards) execute in interpret mode
#                         on the kernel test modules.
#   4. triangle-oracle  — KernelPolicy(triangle='oracle', opm='oracle'):
#                         tier-1 with ONLY the pair-stack kernels pinned to
#                         their jnp oracles (the rest of the kernel set
#                         stays on its default legs) — isolates regressions
#                         to the triangle/OPM fusion itself.
#   5. multi-device     — 8 host devices: distributed DAP/GSPMD parity, the
#                         shard-mapped fused attention + triangle/OPM, and
#                         the fused attention suite, on both kernel legs.
#   6. resilience       — the fault-injection/chaos suite + the serving
#                         suite on BOTH kernel legs, with the process-wide
#                         fault schedule pinned via REPRO_FAULT_SEED
#                         (resolved by envcompat.fault_seed) so the
#                         randomized sweeps are reproducible in CI.
#   7. analysis         — `python -m repro.analysis`: repro-lint (AST) over
#                         src/repro plus the compiled-program contract
#                         matrix on the default and oracle presets
#                         (HLO/jaxpr contracts + modeled-vs-compiled peak
#                         bytes, refreshing BENCH_contracts.json).
#   8. observability    — benchmarks/bench_serving.py --smoke drives a
#                         mixed-length trace through the instrumented
#                         ServingEngine under an obs tracer, refreshing
#                         BENCH_serving.json (measured latency/throughput/
#                         occupancy keyed by serialized ExecutionPlan);
#                         `python -m repro.obs report --strict` then
#                         schema-validates the JSONL event stream + the
#                         bench artifact and checks the request-lifecycle
#                         reconciliation invariant.
# Any divergence between a kernel and its oracle fails fast in legs 1/3;
# legs 2/4 prove the fallback paths stay healthy on their own.
# Leg 7 subsumes the two grep gates this script used to end with:
#   - os.environ confined to src/repro/exec/envcompat.py is repro-lint rule
#     R001 (strictly stronger: also catches `from os import environ`,
#     `os.getenv`, and aliased accessors; tests/test_exec_plan.py enforces
#     the same rule in-suite).
#   - no bare "except Exception:" outside src/repro/resilience/ is rule
#     R002 ("except Exception as err:" with typed re-dispatch stays fine —
#     failures must stay typed so the engine's retry/degradation routing
#     and the tests can see them).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 leg 1/8: plan preset 'default' (XLA-native legs off-TPU) ==="
python -m pytest -x -q "$@"

echo "=== tier-1 leg 2/8: plan preset 'oracle' (REPRO_PLAN=oracle, jnp paths) ==="
REPRO_PLAN=oracle python -m pytest -x -q "$@"

if [ "$#" -gt 0 ]; then
    # Scoped developer run: legs 3-6 run fixed module lists that would ignore
    # the selection — stop here rather than silently dropping the arguments.
    echo "ci.sh: args given — scoped run, legs 1-2 only"
    exit 0
fi

echo "=== tier-1 leg 3/8: plan preset 'interpret' (Pallas interpret validation) ==="
REPRO_PLAN=interpret python -m pytest -x -q \
    tests/test_kernels.py tests/test_fused_attention.py tests/test_triangle.py

echo "=== tier-1 leg 4/8: plan preset 'triangle-oracle' (pair-stack kernels -> oracles) ==="
REPRO_PLAN=triangle-oracle python -m pytest -x -q \
    tests/test_triangle.py tests/test_evoformer.py tests/test_fused_attention.py \
    tests/test_autochunk.py tests/test_alphafold.py

echo "=== tier-1 leg 5/8: multi-device (8 host devices), both kernel legs ==="
XLA_FLAGS="--xla_force_host_platform_device_count=8" python -m pytest -x -q \
    tests/test_distributed.py tests/test_fused_attention.py tests/test_triangle.py
XLA_FLAGS="--xla_force_host_platform_device_count=8" REPRO_PLAN=oracle \
    python -m pytest -x -q tests/test_distributed.py

echo "=== tier-1 leg 6/8: resilience (fault injection + chaos), both kernel legs ==="
REPRO_FAULT_SEED=1234 python -m pytest -x -q \
    tests/test_resilience.py tests/test_serving.py
REPRO_FAULT_SEED=1234 REPRO_PLAN=oracle python -m pytest -x -q \
    tests/test_resilience.py tests/test_serving.py

echo "=== tier-1 leg 7/8: static analysis (repro-lint + compiled-program contracts) ==="
# Replaces the old os.environ / bare-except grep gates (now lint rules R001
# and R002 — see the header comment and repro/analysis/__init__.py for the
# full rule/contract catalog). Lints src/repro, then lowers+compiles the
# contract matrix on the default and oracle presets and cross-validates
# AutoChunk's modeled peak against memory_analysis(), refreshing
# BENCH_contracts.json. Nonzero exit on any finding or violation.
python -m repro.analysis --presets default,oracle

echo "=== tier-1 leg 8/8: observability (bench_serving smoke + schema validation) ==="
# Measured perf-trajectory artifact: the smoke trace refreshes
# BENCH_serving.json (rows keyed by serialized ExecutionPlan for the
# default and oracle presets), then the obs report CLI schema-validates
# the emitted JSONL + the artifact and enforces the lifecycle
# reconciliation invariant (every request reaches exactly one terminal
# state). --strict: any problem is a red gate.
python benchmarks/bench_serving.py --smoke --out BENCH_serving.json \
    --events-out /tmp/obs_serving.jsonl
python -m repro.obs report /tmp/obs_serving.jsonl --bench BENCH_serving.json \
    --strict

echo "ci.sh: all legs green"
