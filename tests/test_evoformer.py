"""Evoformer structural + mathematical tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.evoformer import (
    EvoformerConfig,
    evoformer_block,
    evoformer_stack,
    init_evoformer_block,
    init_evoformer_stack,
    outer_product_mean,
)
from repro.core.dist import LocalDist
from repro.layers.norms import layer_norm
from repro.layers.params import dense


CFG = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2,
                      head_dim=8, opm_dim=8, tri_mult_dim=16, n_blocks=2)


@pytest.fixture
def inputs():
    B, s, r = 2, 6, 10
    msa = jax.random.normal(jax.random.PRNGKey(1), (B, s, r, CFG.d_msa))
    pair = jax.random.normal(jax.random.PRNGKey(2), (B, r, r, CFG.d_pair))
    return (msa, pair, jnp.ones((B, s, r)), jnp.ones((B, r)),
            jnp.ones((B, r, r)))


def test_block_shapes_no_nan(inputs):
    params = init_evoformer_block(jax.random.PRNGKey(0), CFG)
    msa, pair = evoformer_block(params, *inputs, cfg=CFG)
    assert msa.shape == inputs[0].shape and pair.shape == inputs[1].shape
    assert not bool(jnp.isnan(msa).any() or jnp.isnan(pair).any())


def test_stack_grads_finite(inputs):
    params = init_evoformer_stack(jax.random.PRNGKey(0), CFG)

    def loss(p):
        m, z = evoformer_stack(p, *inputs, cfg=CFG, remat=True)
        return jnp.sum(m ** 2) + jnp.sum(z ** 2)

    g = jax.grad(loss)(params)
    total = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0


def test_remat_matches_no_remat(inputs):
    params = init_evoformer_stack(jax.random.PRNGKey(0), CFG)
    m1, z1 = evoformer_stack(params, *inputs, cfg=CFG, remat=True)
    m2, z2 = evoformer_stack(params, *inputs, cfg=CFG, remat=False)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-6)


def test_opm_matches_direct_einsum(inputs):
    """Outer-product-mean vs its textbook definition einsum(bsid,bsje->bijde)."""
    params = init_evoformer_block(jax.random.PRNGKey(0), CFG)["opm"]
    msa, _, msa_mask, _, _ = inputs
    got = outer_product_mean(params, msa, msa_mask, LocalDist(), CFG)

    m_n = layer_norm(params["ln"], msa)
    ab = dense(params["proj"], m_n)
    a, b = jnp.split(ab, 2, axis=-1)
    o = jnp.einsum("bsid,bsje->bijde", a, b) / msa.shape[1]
    want = dense(params["out"],
                 o.reshape(o.shape[:3] + (-1,)) * (msa.shape[1] /
                                                   (msa.shape[1] + 1e-3)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-3)


def test_msa_row_permutation_equivariance(inputs):
    """Permuting MSA rows (non-target) permutes the MSA output identically
    and leaves the pair output unchanged — a core Evoformer symmetry."""
    params = init_evoformer_block(jax.random.PRNGKey(0), CFG)
    msa, pair, msa_mask, seq_mask, pair_mask = inputs
    perm = jnp.array([3, 0, 5, 1, 4, 2])
    m1, z1 = evoformer_block(params, msa, pair, msa_mask, seq_mask, pair_mask,
                             cfg=CFG)
    m2, z2 = evoformer_block(params, msa[:, perm], pair, msa_mask, seq_mask,
                             pair_mask, cfg=CFG)
    np.testing.assert_allclose(np.asarray(m1[:, perm]), np.asarray(m2),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=2e-5)


def test_inference_chunking_equivalent(inputs):
    """Paper §V.C chunking technique must be numerically identical."""
    import dataclasses
    params = init_evoformer_block(jax.random.PRNGKey(0), CFG)
    m1, z1 = evoformer_block(params, *inputs, cfg=CFG)
    cfg_c = dataclasses.replace(CFG, inference_chunk=3)
    m2, z2 = evoformer_block(params, *inputs, cfg=cfg_c)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=2e-5)


def test_masked_positions_do_not_leak(inputs):
    """Changing MSA content at masked-out sequence positions must not change
    outputs at valid positions."""
    params = init_evoformer_block(jax.random.PRNGKey(0), CFG)
    msa, pair, msa_mask, seq_mask, pair_mask = inputs
    seq_mask = seq_mask.at[:, -2:].set(0.0)
    pair_mask = seq_mask[:, :, None] * seq_mask[:, None, :]
    m1, z1 = evoformer_block(params, msa, pair, msa_mask, seq_mask, pair_mask,
                             cfg=CFG)
    msa2 = msa.at[:, :, -2:, :].add(100.0)
    m2, z2 = evoformer_block(params, msa2, pair, msa_mask, seq_mask,
                             pair_mask, cfg=CFG)
    np.testing.assert_allclose(np.asarray(m1[:, :, :-2]),
                               np.asarray(m2[:, :, :-2]), atol=2e-4)
