"""Production meshes. TPU v5e: 256 chips/pod, 16x16 ICI torus.

make_production_mesh is a FUNCTION so importing this module never touches jax
device state (the dry-run sets the 512-device XLA flag before first init).
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """jax.make_mesh across jax versions: axis_types (Auto) when the running
    jax supports it, plain mesh otherwise (pre-0.5 jax has no AxisType and
    defaults to the same auto behavior)."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int | None = None):
    """Small mesh over the actual local devices (tests, examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return _mesh((data, model), ("data", "model"))


# Hardware constants for the roofline (TPU v5e).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
HBM_BYTES = 16 << 30          # 16 GB per chip
