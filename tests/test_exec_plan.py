"""ExecutionPlan semantics (repro/exec): env-compat round-trip + late
binding (the old import-time KERNELS_ENABLED bug), nested use_plan scoping,
the hashability/jit-cache contract, leg-numerics parity, MemoryPolicy
overrides, AsyncPolicy gating, per-request serving plans, the FastFold
facade, and the no-env-access-outside-envcompat gate."""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import duality
from repro.exec.plan import (
    ExecutionPlan,
    KernelPolicy,
    MemoryPolicy,
    current_plan,
    preset,
    use_plan,
)
from repro.kernels import ops

_LEGACY_VARS = ("REPRO_PLAN", "REPRO_DISABLE_KERNELS",
                "REPRO_PALLAS_INTERPRET", "REPRO_FORCE_TRIANGLE_ORACLE",
                "REPRO_FORCE_SCAN_ATTN_BWD")


@pytest.fixture
def clean_env(monkeypatch):
    for v in _LEGACY_VARS:
        monkeypatch.delenv(v, raising=False)
    return monkeypatch


# ---------------------------------------------------------------------------
# env compat
# ---------------------------------------------------------------------------


def test_from_env_round_trips_all_legacy_flags(clean_env):
    assert ExecutionPlan.from_env() == ExecutionPlan()
    clean_env.setenv("REPRO_DISABLE_KERNELS", "1")
    clean_env.setenv("REPRO_PALLAS_INTERPRET", "1")
    clean_env.setenv("REPRO_FORCE_TRIANGLE_ORACLE", "1")
    clean_env.setenv("REPRO_FORCE_SCAN_ATTN_BWD", "1")
    k = ExecutionPlan.from_env().kernels
    assert k == KernelPolicy(enabled=False, interpret=True, triangle="oracle",
                             opm="oracle", attn_bwd="scan")


def test_from_env_plan_presets_and_composition(clean_env):
    clean_env.setenv("REPRO_PLAN", "triangle-oracle")
    k = ExecutionPlan.from_env().kernels
    assert (k.triangle, k.opm, k.enabled) == ("oracle", "oracle", True)
    # legacy flags layer ON TOP of the preset
    clean_env.setenv("REPRO_PALLAS_INTERPRET", "1")
    k = ExecutionPlan.from_env().kernels
    assert (k.triangle, k.interpret) == ("oracle", True)
    clean_env.setenv("REPRO_PLAN", "no-such-preset")
    with pytest.raises(KeyError):
        ExecutionPlan.from_env()


def test_env_flags_bind_at_plan_construction_not_import(clean_env):
    """Regression for the import-order bug: KERNELS_ENABLED used to be read
    from the environment at import time, so setting REPRO_DISABLE_KERNELS
    *after* `import repro.kernels.ops` silently did nothing. Now the flag is
    read when the plan is constructed — long after every import."""
    assert current_plan().kernels.enabled
    assert ops.fused_attention_supported((2, 8, 2, 4))
    clean_env.setenv("REPRO_DISABLE_KERNELS", "1")   # post-import!
    assert not current_plan().kernels.enabled
    assert not ops.fused_attention_supported((2, 8, 2, 4))
    assert not ops.fused_triangle_supported(16, 12, jnp.float32)
    clean_env.delenv("REPRO_DISABLE_KERNELS")
    assert ops.fused_attention_supported((2, 8, 2, 4))


# ---------------------------------------------------------------------------
# scoping
# ---------------------------------------------------------------------------


def test_nested_use_plan_scopes_restore(clean_env):
    outer = preset("interpret")
    inner = preset("oracle")
    base = current_plan()
    with use_plan(outer):
        assert current_plan() is outer
        with use_plan(inner):
            assert current_plan() is inner
        assert current_plan() is outer
    assert current_plan() == base


def test_use_plan_scope_restores_on_exception(clean_env):
    base = current_plan()
    with pytest.raises(RuntimeError):
        with use_plan(preset("oracle")):
            raise RuntimeError("boom")
    assert current_plan() == base


def test_use_plan_rejects_non_plan():
    with pytest.raises(TypeError):
        with use_plan("oracle"):
            pass


def test_kernel_policy_validates_legs():
    with pytest.raises(ValueError):
        KernelPolicy(triangle="pallass")
    with pytest.raises(ValueError):
        KernelPolicy(attn_bwd="oracle")


# ---------------------------------------------------------------------------
# hashability / jit-cache contract + leg numerics
# ---------------------------------------------------------------------------


def test_two_plans_two_jit_cache_entries_identical_numerics(clean_env):
    """Two different plans on identical shapes produce distinct jit cache
    entries (the hashability contract) and identical numerics for the
    pallas/xla-vs-oracle attention legs; an equal plan (fresh instance) must
    NOT retrace."""
    traces = []

    @functools.partial(jax.jit, static_argnums=0)
    def run(plan, q):
        traces.append(plan)
        with use_plan(plan):
            return ops.fused_attention(q, q, q)

    q = jax.random.normal(jax.random.PRNGKey(0), (2, 12, 2, 8))
    default, oracle = ExecutionPlan(), preset("oracle")
    assert hash(default) == hash(ExecutionPlan())
    assert hash(default) != hash(oracle)

    y_fused = run(default, q)
    y_again = run(ExecutionPlan(), q)       # equal plan -> cache hit
    assert len(traces) == 1
    y_oracle = run(oracle, q)
    assert len(traces) == 2                 # distinct plan -> new entry
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_again),
                               atol=0)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_oracle),
                               atol=1e-6)


def test_triangle_opm_legs_identical_under_plan_scopes(clean_env):
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    B, S, I, C, D = 1, 4, 6, 8, 10
    a = jax.random.normal(ks[0], (B, S, I, C))
    b = jax.random.normal(ks[1], (B, S, I, C))
    ma = jnp.ones((B, S, I))
    mb = jnp.ones((B, S, I))
    w = jax.random.normal(ks[2], (C * C, D))
    bias = jax.random.normal(ks[3], (D,))
    outs = {}
    for name in ("default", "oracle", "triangle-oracle"):
        with use_plan(preset(name)):
            outs[name] = ops.fused_outer_product_mean(a, b, ma, mb, w, bias)
    for name in ("oracle", "triangle-oracle"):
        np.testing.assert_allclose(np.asarray(outs["default"]),
                                   np.asarray(outs[name]), atol=2e-5)


def test_attn_bwd_choice_baked_at_call_time(clean_env):
    """KernelPolicy.attn_bwd is resolved when the op is CALLED, so a
    use_plan scope around the op call governs the backward even though jax
    traces the custom_vjp bwd after the scope exits — and the two backward
    legs agree numerically."""
    q = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 2, 8))

    def loss_default(q_):
        return jnp.sum(ops.fused_attention(q_, q_, q_, kv_tile=8) ** 2)

    def loss_scan(q_):
        with use_plan(current_plan().with_kernels(attn_bwd="scan")):
            return jnp.sum(ops.fused_attention(q_, q_, q_, kv_tile=8) ** 2)

    g_default = jax.grad(loss_default)(q)
    g_scan = jax.grad(loss_scan)(q)
    np.testing.assert_allclose(np.asarray(g_default), np.asarray(g_scan),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# MemoryPolicy / AsyncPolicy
# ---------------------------------------------------------------------------


def test_memory_policy_overrides_evoformer_knobs():
    from repro.core.evoformer import EvoformerConfig

    cfg = EvoformerConfig()
    pol = MemoryPolicy(attn_kv_tile=64, tri_k_tile=32, auto_chunk=False)
    out = pol.apply(cfg)
    assert (out.attn_kv_tile, out.tri_k_tile, out.auto_chunk) == (64, 32,
                                                                  False)
    assert out.opm_chunk == cfg.opm_chunk
    assert MemoryPolicy().apply(cfg) is cfg  # no overrides -> same object


def test_async_policy_gates_overlap_window(clean_env):
    x = jnp.ones((3,))
    y = jnp.ones((4,))
    with use_plan(ExecutionPlan().with_async(overlap_windows=False)):
        cx, ix = duality.overlap_window(x, y)
        assert cx is x and ix is y           # pure passthrough, no barrier
    cx, ix = duality.overlap_window(x, y)
    assert cx is not x                       # barrier emitted new values
    np.testing.assert_allclose(np.asarray(cx), np.asarray(x))


# ---------------------------------------------------------------------------
# serving: per-request plans
# ---------------------------------------------------------------------------


def test_serving_engine_mixed_plan_traffic(clean_env):
    from repro.configs import get_config
    from repro.models.decoder import init_model
    from repro.serving.engine import ServingEngine

    cfg = get_config("qwen2-1.5b", reduced_variant=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=(5 + i,)) for i in range(3)]

    eng_ref = ServingEngine(params, cfg, n_slots=3, max_seq=32)
    want = [eng_ref.submit(p, max_new_tokens=4) for p in prompts]
    eng_ref.run()

    # Same traffic, middle request on the oracle-leg canary plan: runs in
    # the same engine (two decode groups per step) with identical greedy
    # output — no global toggles, no cross-request leakage.
    eng = ServingEngine(params, cfg, n_slots=3, max_seq=32)
    canary = preset("oracle")
    got = [eng.submit(p, max_new_tokens=4,
                      plan=canary if i == 1 else None)
           for i, p in enumerate(prompts)]
    eng.run()
    assert got[1].plan == canary
    assert len(eng._decode_fns) == 2         # one jit wrapper per plan
    for w, g in zip(want, got):
        assert w.generated == g.generated


# ---------------------------------------------------------------------------
# FastFold facade
# ---------------------------------------------------------------------------


def test_fastfold_facade_forward_train_serve(clean_env):
    from repro.configs.alphafold import SMOKE
    from repro.data import protein_batches
    from repro.exec.session import FastFold

    ff = FastFold(SMOKE)
    params = ff.init(jax.random.PRNGKey(0))
    pb = next(protein_batches(batch=1, n_seq=4, n_res=8, seed=0))
    batch = {k: jnp.asarray(getattr(pb, k)) for k in
             ("msa", "msa_mask", "residue_index", "aatype", "seq_mask",
              "pseudo_beta", "bert_mask", "true_msa")}
    out = ff.forward(params, batch)
    assert out["coords"].shape == (1, 8, 3)
    loss, metrics = ff.train_loss(params, batch, jax.random.PRNGKey(1))
    assert np.isfinite(float(loss))
    # per-request plan override through the serving entry point
    outs = ff.serve(params, [batch, batch],
                    plans=[None, preset("oracle")])
    np.testing.assert_allclose(np.asarray(outs[0]["coords"]),
                               np.asarray(outs[1]["coords"]), atol=1e-4)
    with pytest.raises(ValueError):
        ff.serve(params, [batch], plans=[None, None])


# ---------------------------------------------------------------------------
# the env gate, enforced in tier-1 too
# ---------------------------------------------------------------------------


def test_plan_json_round_trip_all_presets():
    """to_json/from_json round-trips every preset to an equal AND
    equal-hash plan — a deserialized plan must hit the same jit cache
    entries as the original (the hashability contract, extended across
    process boundaries)."""
    from repro.exec.plan import PRESETS

    for name, plan in PRESETS.items():
        back = ExecutionPlan.from_json(plan.to_json())
        assert back == plan, name
        assert hash(back) == hash(plan), name
        # canonical form: equal plans serialize to equal strings
        assert back.to_json() == plan.to_json(), name
        d = plan.to_dict()
        assert set(d) == {"kernels", "parallel", "memory", "duality"}, name
        assert ExecutionPlan.from_dict(d) == plan, name


def test_plan_serialization_validates_and_rejects_mesh():
    degraded = preset("default").degrade()
    assert ExecutionPlan.from_json(degraded.to_json()) == degraded
    # from_dict goes through the policies' __post_init__ validation
    bad = preset("default").to_dict()
    bad["kernels"]["triangle"] = "quantum"
    with pytest.raises(ValueError, match="triangle"):
        ExecutionPlan.from_dict(bad)
    # a live mesh is a device handle, not data
    meshy = preset("default").with_parallel(backend="gspmd", mesh=object())
    with pytest.raises(ValueError, match="mesh"):
        meshy.to_dict()


def test_no_env_access_outside_envcompat():
    """Env access under src/repro is confined to the single compat module
    (exec/envcompat.py) — repro-lint rule R001, the same gate ci.sh leg 7
    runs. Strictly stronger than the old `os.environ` string scan: the AST
    pass also catches `from os import environ` and `os.getenv` aliases."""
    from repro.analysis.lint import lint_tree

    root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    offenders = [f.render() for f in lint_tree(root) if f.rule == "R001"]
    assert not offenders, (
        f"env access outside exec/envcompat.py (R001): {offenders}")
