"""Rotary position embeddings (interleaved-half convention, LLaMA-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    assert head_dim % 2 == 0
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # (head_dim/2,)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
