"""Batched serving engine: slot-based continuous batching over the decoder's
prefill/decode steps (the inference-side counterpart of the paper's
distributed long-sequence inference — the same model_forward lowers under
DAP/GSPMD shardings for the multi-device path).

Design: a fixed number of slots share one batched KV cache. Requests are
admitted into free slots (B=1 prefill, cache rows scattered into the slot),
all active slots advance together with one batched decode step per token,
finished sequences free their slots immediately.

Memory: the engine's attention blocks come from the AutoChunk planner
(repro.memory.autochunk.plan_decoder_blocks) — the configured
``attn_q_block``/``attn_kv_block`` are kept when the KV cache + prefill
transients fit the HBM budget and shrunk (KV block first) when they don't.
``auto_plan=False`` restores the raw config.

Execution policy: the engine binds one ExecutionPlan (default: the ambient
``current_plan()``), and ``submit(..., plan=...)`` overrides it per request —
e.g. oracle-leg canary requests beside production pallas-leg requests in the
same engine, with no process-global toggles. Each request's prefill runs
under its own plan; decode steps group the active slots by plan and run one
batched decode per distinct plan (each with its own jit cache entry, so
plans never share a trace), committing only that group's cache rows — slots
are independent in a decode step, so discarding the other rows is exact.
The engine's HBM budget for the block planner defaults to the bound plan's
MemoryPolicy.

Failure handling (the production story — every path deterministic under
``resilience.inject_faults``, see repro/resilience/__init__.py for the full
fault-site/retry/degradation matrix):

  * Admission control. ``submit`` rejects, with typed ``AdmissionError``
    backpressure, prompts over ``max_seq``, submissions past the bounded
    pending queue (``max_pending``), and requests whose ``(plan, length)``
    would exceed the ``check_decoder_admission`` HBM model under the plan's
    ``MemoryPolicy.hbm_budget``. ``admission_control=False`` defers the HBM
    check to admission time (queue-then-fail instead of reject-at-submit).
  * Deadlines. ``submit(..., deadline=N)`` fails the request with
    ``DeadlineExceeded`` once N engine steps elapse, queued or active.
  * Retry. ``submit(..., retry=RetryPolicy(...))`` requeues retryable
    failures (transient decode faults, stage timeouts, optionally
    quarantined non-finite slots) through the slot teardown invariant with
    capped exponential backoff measured in engine steps — the retry
    re-prefills from scratch, so tokens are never lost or duplicated.
  * Non-finite guard. Every decode group's logits carry an in-trace
    per-slot finiteness flag (trace-time overhead only — outputs are
    bit-identical with the guard in place); non-finite slots are
    quarantined individually instead of poisoning the whole batch.
  * Graceful degradation. OOM (injected ``OomFault`` or a real
    RESOURCE_EXHAUSTED) retries the request under ``plan.degrade()`` rungs
    (tighter MemoryPolicy chunks -> oracle kernel leg), recording each
    fallback plan on ``Request.fallback_chain``; a request whose ladder is
    exhausted fails typed.
  * No livelock. ``run()`` detects a non-empty queue that can make no
    progress (e.g. an over-budget plan with submit-time admission off) and
    fails those requests typed instead of spinning.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.exec.plan import ExecutionPlan, current_plan, use_plan
from repro.launch.mesh import HBM_BYTES
from repro.memory.autochunk import check_decoder_admission, plan_decoder_blocks
from repro.models.decoder import init_cache, model_forward
from repro.obs import trace as obs
from repro.resilience.errors import AdmissionError, DeadlineExceeded
from repro.resilience.faults import InjectedFault, NonFiniteFault, fire, is_oom
from repro.resilience.retry import RetryPolicy


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                     # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0               # 0 => greedy
    eos_id: Optional[int] = None
    # execution plan this request runs under (engine default when None)
    plan: Optional[ExecutionPlan] = None
    # failure policy: deadline in engine steps, retry policy for transients
    deadline: Optional[int] = None
    retry: Optional[RetryPolicy] = None
    # outputs / lifecycle
    generated: list = field(default_factory=list)
    done: bool = False
    status: str = "queued"                 # queued | active | done | failed
    error: Optional[BaseException] = None
    attempts: int = 0                      # admissions started (prefills)
    fallback_chain: list = field(default_factory=list)  # degraded plans
    # internal scheduling state (engine steps)
    _ready_step: int = 0
    _deadline_step: Optional[int] = None


def sample_token(logits, rng, temperature: float):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(rng, logits / temperature, axis=-1)


# Module-level jitted steps with the (hashable) config and plan as static
# arguments: engines over the same model share traces — a chaos sweep
# building 25 engines pays for each (cfg, plan, shape) trace once.

@partial(jax.jit, static_argnames=("cfg", "plan", "max_cache_len"))
def _prefill_step(params, prompt, *, cfg: ModelConfig, plan: ExecutionPlan,
                  max_cache_len: int):
    with use_plan(plan):
        return model_forward(params, prompt, cfg, mode="prefill",
                             max_cache_len=max_cache_len)


@partial(jax.jit, static_argnames=("cfg", "plan"))
def _decode_step(params, toks, cache, lengths, *, cfg: ModelConfig,
                 plan: ExecutionPlan):
    with use_plan(plan):
        out = model_forward(params, toks, cfg, mode="decode", cache=cache,
                            lengths=lengths)
    # Per-slot non-finite guard, computed inside the trace (no extra host
    # round-trip beyond this tiny flag vector, and no change to the logits).
    finite = jnp.all(jnp.isfinite(out["logits"]), axis=(1, 2))
    return out, finite


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_seq: int = 512, dtype=jnp.bfloat16,
                 auto_plan: bool = True, hbm_budget: int | None = None,
                 plan: ExecutionPlan | None = None,
                 max_pending: int | None = 256,
                 admission_control: bool = True):
        self.params = params
        self.plan = plan if plan is not None else current_plan()
        if hbm_budget is None:
            hbm_budget = self.plan.memory.hbm_budget or HBM_BYTES
        self._hbm_budget = hbm_budget
        if auto_plan:
            cfg, self.block_plan = plan_decoder_blocks(
                cfg, n_slots=n_slots, max_seq=max_seq,
                budget_bytes=hbm_budget)
        else:
            self.block_plan = None
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.max_pending = max_pending
        self.admission_control = admission_control
        self.cache = init_cache(cfg, n_slots, max_seq, dtype)
        self.lengths = jnp.zeros((n_slots,), jnp.int32)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.pending: list[Request] = []
        self.finished: list[Request] = []
        self._rng = jax.random.PRNGKey(0)
        self._next_uid = 0
        self._step_count = 0
        # One decode entry per distinct ExecutionPlan seen in traffic (the
        # plan steers trace-time branches — traces must not be shared).
        self._decode_fns: dict[ExecutionPlan, Callable] = {}
        # Model facts for obs meta events + the roofline cross-reference,
        # from array *metadata* only (no device sync).
        leaves = jax.tree.leaves(self.params)
        self._param_count = sum(x.size for x in leaves)
        self._param_bytes = sum(x.size * x.dtype.itemsize for x in leaves)
        self._cache_row_bytes = sum(
            (x.size // n_slots // max_seq) * x.dtype.itemsize
            for x in jax.tree.leaves(self.cache))
        self._meta_emitted: set[int] = set()

    # --- observability (every hook below is a no-op when no tracer is
    # scoped — see repro/obs) ---

    def _tr(self):
        """Current tracer (or None), emitting the engine's run-metadata
        event once per tracer."""
        tr = obs.current_tracer()
        if tr is not None and id(tr) not in self._meta_emitted:
            self._meta_emitted.add(id(tr))
            tr.emit("meta", "engine", attrs={
                "model": self.cfg.name, "n_slots": self.n_slots,
                "max_seq": self.max_seq,
                "param_count": self._param_count,
                "param_bytes": self._param_bytes,
                "cache_row_bytes": self._cache_row_bytes,
                "plan": self._plan_label(tr, self.plan)})
        return tr

    @staticmethod
    def _plan_label(tr, plan: ExecutionPlan) -> str:
        """Interned ``plan:N`` label for events — the full serialized plan
        appears once in a ``def`` event; a live mesh (not serializable)
        falls back to the describe() string."""
        try:
            val = plan.to_dict()
        except ValueError:
            val = plan.describe()
        return tr.define("plan", val)

    def _req_event(self, tr, phase: str, req: Optional[Request], **attrs):
        tr.emit("request", phase,
                uid=req.uid if req is not None else None, attrs=attrs)

    def _decode_for(self, plan: ExecutionPlan):
        fn = self._decode_fns.get(plan)
        if fn is None:
            fn = partial(_decode_step, cfg=self.cfg, plan=plan)
            self._decode_fns[plan] = fn
        return fn

    def _admission(self, req: Request):
        budget = req.plan.memory.hbm_budget or self._hbm_budget
        return check_decoder_admission(
            self.cfg, n_slots=self.n_slots, max_seq=self.max_seq,
            seq_len=int(req.prompt.shape[-1]), budget_bytes=budget)

    def submit(self, prompt: np.ndarray, *,
               plan: ExecutionPlan | None = None,
               deadline: int | None = None,
               retry: RetryPolicy | None = None, **kw) -> Request:
        """Queue a request. ``plan`` overrides the engine's bound
        ExecutionPlan for this request only (prefill + its decode group);
        ``deadline`` is a budget in engine steps; ``retry`` opts retryable
        failures into slot-safe requeue with backoff. Raises
        ``AdmissionError`` (typed backpressure) on over-length prompts, a
        full pending queue, or a (plan, length) over the HBM model."""
        tr = self._tr()
        prompt = np.asarray(prompt, np.int32)
        if prompt.shape[-1] > self.max_seq:
            # Admitting an over-length prompt would prefill past the cache
            # extent and make every later decode step clamp its .at[].set
            # into the last cache row — silent KV corruption for the whole
            # batch. Reject at the API boundary instead.
            if tr is not None:
                self._req_event(tr, "rejected", None, reason="over_length",
                                prompt_len=int(prompt.shape[-1]))
            raise AdmissionError(
                f"prompt length {prompt.shape[-1]} exceeds the engine's "
                f"max_seq={self.max_seq}")
        if self.max_pending is not None and \
                len(self.pending) >= self.max_pending:
            if tr is not None:
                self._req_event(tr, "rejected", None, reason="queue_full",
                                queue_depth=len(self.pending))
            raise AdmissionError(
                f"pending queue full ({self.max_pending} requests): "
                f"backpressure — drain or retry later")
        req = Request(uid=self._next_uid, prompt=prompt,
                      plan=plan if plan is not None else self.plan,
                      deadline=deadline, retry=retry, **kw)
        if self.admission_control:
            chk = self._admission(req)
            if not chk.fits:
                if tr is not None:
                    self._req_event(tr, "rejected", None, reason="hbm_model",
                                    prompt_len=int(prompt.shape[-1]),
                                    plan=self._plan_label(tr, req.plan))
                raise AdmissionError(
                    f"request would exceed the HBM model under its plan: "
                    f"{chk.describe()}")
        self._next_uid += 1
        if deadline is not None:
            req._deadline_step = self._step_count + deadline
        self.pending.append(req)
        if tr is not None:
            self._req_event(tr, "queued", req,
                            prompt_len=int(prompt.shape[-1]),
                            plan=self._plan_label(tr, req.plan),
                            queue_depth=len(self.pending),
                            deadline=deadline)
        return req

    # --- internals ---

    def _teardown(self, slot: int):
        """Free a slot (single source of the teardown invariant — release,
        failure, quarantine, and requeue all come through here)."""
        self.slot_req[slot] = None
        self.lengths = self.lengths.at[slot].set(0)

    def _release(self, slot: int, req: Request):
        """Finish a request successfully and free its slot."""
        req.done = True
        req.status = "done"
        self.finished.append(req)
        self._teardown(slot)
        tr = self._tr()
        if tr is not None:
            self._req_event(tr, "done", req, slot=slot,
                            step=self._step_count,
                            tokens=len(req.generated),
                            attempts=req.attempts,
                            degraded=len(req.fallback_chain))

    def _fail(self, slot: Optional[int], req: Request, err: BaseException):
        """Terminate a request with a typed error (slot=None: not admitted)."""
        if slot is not None:
            self._teardown(slot)
        req.status = "failed"
        req.error = err
        self.finished.append(req)
        tr = self._tr()
        if tr is not None:
            self._req_event(tr, "failed", req, slot=slot,
                            step=self._step_count,
                            error=type(err).__name__,
                            tokens=len(req.generated),
                            attempts=req.attempts)

    def _requeue(self, slot: Optional[int], req: Request, *, ready: int):
        """Slot-safe requeue: tear the slot down through the same invariant
        as release, discard the attempt's tokens (the retry re-prefills
        from scratch — nothing is lost or duplicated), and queue at the
        front, eligible from engine step ``ready``."""
        if slot is not None:
            self._teardown(slot)
        req.generated = []
        req.status = "queued"
        req._ready_step = ready
        self.pending.insert(0, req)
        tr = self._tr()
        if tr is not None:
            self._req_event(tr, "retried", req, slot=slot,
                            step=self._step_count, ready=ready,
                            attempt=req.attempts)

    def _dispatch_failure(self, slot: Optional[int], req: Request,
                          err: BaseException):
        """Route a failure to its handler: OOM -> degradation ladder;
        retryable under the request's policy -> requeue with backoff;
        other typed faults -> fail. Unrecognized errors are bugs and
        re-raise."""
        if is_oom(err):
            nxt = req.plan.degrade()
            if nxt is not None:
                req.fallback_chain.append(nxt)
                req.plan = nxt
                tr = self._tr()
                if tr is not None:
                    self._req_event(tr, "degraded", req,
                                    step=self._step_count,
                                    rung=len(req.fallback_chain),
                                    plan=self._plan_label(tr, nxt))
                self._requeue(slot, req, ready=self._step_count + 1)
            else:
                self._fail(slot, req, err)
            return
        if isinstance(err, InjectedFault):
            pol = req.retry
            if pol is not None and pol.should_retry(err, req.attempts):
                delay = pol.delay_steps(req.attempts, seed=req.uid)
                self._requeue(slot, req, ready=self._step_count + delay)
            else:
                self._fail(slot, req, err)
            return
        raise err

    def _poison_slot(self, slot: int):
        """Injected NonFiniteFault: NaN the slot's floating cache rows so
        the in-trace guard catches the corruption end to end (a requeued
        request's re-prefill overwrites these rows)."""
        def poison(x):
            if jnp.issubdtype(x.dtype, jnp.floating):
                return x.at[:, slot].set(jnp.nan)
            return x

        self.cache = jax.tree.map(poison, self.cache)

    def _next_admissible(self) -> Optional[Request]:
        """Pop the first pending request that is ready (backoff elapsed)
        and fits the HBM model (FIFO among eligible)."""
        for i, req in enumerate(self.pending):
            if req._ready_step > self._step_count:
                continue
            if not self._admission(req).fits:
                continue
            return self.pending.pop(i)
        return None

    def _prefill(self, slot: int, req: Request) -> bool:
        """Admit ``req`` into ``slot``. Returns False when a fault rerouted
        the request (requeued or failed) instead."""
        req.attempts += 1
        prompt = jnp.asarray(req.prompt)[None]            # (1, S)
        tr = self._tr()
        if tr is not None:
            self._req_event(tr, "admitted", req, slot=slot,
                            step=self._step_count, attempt=req.attempts,
                            prompt_len=int(req.prompt.shape[-1]),
                            plan=self._plan_label(tr, req.plan))
        try:
            for f in fire("prefill", step=self._step_count, slot=slot,
                          uid=req.uid, attempt=req.attempts, plan=req.plan):
                raise f
            if tr is not None:
                tr.jit_entry("prefill", self._plan_label(tr, req.plan))
            out = obs.timed_call(
                "prefill", _prefill_step, self.params, prompt, cfg=self.cfg,
                plan=req.plan, max_cache_len=self.max_seq,
                attrs={"uid": req.uid, "slot": slot,
                       "prompt_len": int(req.prompt.shape[-1])})
        except Exception as err:
            if not (isinstance(err, InjectedFault) or is_oom(err)):
                raise
            self._dispatch_failure(None, req, err)
            return False
        # scatter the single-row cache into this slot
        self.cache = jax.tree.map(
            lambda full, one: full.at[:, slot].set(one[:, 0]),
            self.cache, out["cache"])
        self.lengths = self.lengths.at[slot].set(len(req.prompt))
        self.slot_req[slot] = req
        req.status = "active"
        if tr is not None:
            self._req_event(tr, "prefill", req, slot=slot,
                            step=self._step_count)
        # first generated token comes from the prefill logits
        self._emit(slot, out["logits"][0, -1], req)
        return True

    def _admit(self) -> bool:
        admitted = False
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None:
                continue
            req = self._next_admissible()
            if req is None:
                break
            admitted |= self._prefill(slot, req)
        return admitted

    def _expire_deadlines(self):
        now = self._step_count
        for slot, req in enumerate(self.slot_req):
            if req is not None and req._deadline_step is not None \
                    and now > req._deadline_step:
                self._fail(slot, req, DeadlineExceeded(
                    f"request {req.uid}: deadline of {req.deadline} engine "
                    f"steps exceeded while active"))
        for req in [r for r in self.pending if r._deadline_step is not None
                    and now > r._deadline_step]:
            self.pending.remove(req)
            self._fail(None, req, DeadlineExceeded(
                f"request {req.uid}: deadline of {req.deadline} engine "
                f"steps exceeded while queued"))

    def _emit(self, slot: int, logits, req: Request):
        self._rng, sub = jax.random.split(self._rng)
        tok = int(sample_token(logits, sub, req.temperature))
        req.generated.append(tok)
        obs.count("tokens", slot=slot, uid=req.uid)
        if (req.eos_id is not None and tok == req.eos_id) or \
                len(req.generated) >= req.max_new_tokens:
            self._release(slot, req)

    def _retire_full(self):
        """Force-finish any slot whose sequence reached max_seq: there is no
        cache row left for another decode write — letting step() run would
        clamp the .at[lengths].set into row max_seq-1 and corrupt the KV
        cache for the remaining tokens."""
        lengths = np.asarray(self.lengths)  # one host read per step, not per slot
        for slot, req in enumerate(self.slot_req):
            if req is not None and int(lengths[slot]) >= self.max_seq:
                self._release(slot, req)

    def step(self):
        """One batched decode step across all active slots — one decode call
        per distinct request plan (slots in a decode step are independent, so
        each plan group commits only its own cache rows and logits).
        Returns True when anything progressed (decode, admission, release,
        or a handled failure)."""
        self._step_count += 1
        tr = self._tr()
        if tr is None:
            return self._step_inner(None)
        tr.gauge("queue_depth", len(self.pending), step=self._step_count)
        with tr.span("engine.step", step=self._step_count):
            return self._step_inner(tr)

    def _step_inner(self, tr):
        terminal_before = len(self.finished)
        self._expire_deadlines()
        admitted = self._admit()
        self._retire_full()

        def active_slots():
            return [s for s, r in enumerate(self.slot_req) if r is not None]

        active = active_slots()
        if tr is not None:
            tr.gauge("occupancy", len(active), step=self._step_count)
        if not active:
            return admitted or len(self.finished) != terminal_before

        # Decode-site fault injection, per slot, before the batched call.
        for s in active:
            req = self.slot_req[s]
            for f in fire("decode", step=self._step_count, slot=s,
                          uid=req.uid, attempt=req.attempts, plan=req.plan):
                if isinstance(f, NonFiniteFault):
                    self._poison_slot(s)      # the in-trace guard catches it
                else:
                    self._dispatch_failure(s, req, f)
                    break
        active = active_slots()
        if not active:
            return True

        toks = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.slot_req[s].generated[-1]
        toks = jnp.asarray(toks)

        groups: dict[ExecutionPlan, list[int]] = {}
        for s in active:
            groups.setdefault(self.slot_req[s].plan, []).append(s)

        new_cache = self.cache
        logits_by_slot: dict[int, jax.Array] = {}
        finite_by_slot: dict[int, bool] = {}
        decoded: list[int] = []
        failed_groups = 0
        for plan_, slots in groups.items():
            try:
                if tr is not None:
                    label = self._plan_label(tr, plan_)
                    tr.jit_entry("decode", label)
                    out, finite = tr.timed_call(
                        "decode", self._decode_for(plan_),
                        self.params, toks, self.cache, self.lengths,
                        attrs={"plan": label, "batch": len(slots),
                               "step": self._step_count})
                else:
                    out, finite = self._decode_for(plan_)(
                        self.params, toks, self.cache, self.lengths)
            except Exception as err:
                if not is_oom(err):
                    raise
                failed_groups += 1
                for s in slots:
                    self._dispatch_failure(s, self.slot_req[s], err)
                continue
            if len(groups) == 1 and not failed_groups:
                new_cache = out["cache"]
            else:
                idx = jnp.asarray(slots)
                new_cache = jax.tree.map(
                    lambda acc, new: acc.at[:, idx].set(new[:, idx]),
                    new_cache, out["cache"])
            finite = np.asarray(finite)
            logits = out["logits"][:, 0]
            for s in slots:
                logits_by_slot[s] = logits[s]
                finite_by_slot[s] = bool(finite[s])
            decoded.extend(slots)
        self.cache = new_cache
        self.lengths = self.lengths + jnp.asarray(
            [1 if (s in decoded and self.slot_req[s] is not None) else 0
             for s in range(self.n_slots)], jnp.int32)
        for s in decoded:
            req = self.slot_req[s]
            if req is None:
                continue
            if not finite_by_slot[s]:
                # Quarantine ONLY this slot: its logits are garbage and its
                # cache row is poisoned, but slots are independent per step
                # — the rest of the batch is untouched.
                if tr is not None:
                    self._req_event(tr, "quarantined", req, slot=s,
                                    step=self._step_count)
                self._dispatch_failure(s, req, NonFiniteFault(
                    f"request {req.uid}: non-finite logits in decode group "
                    f"— slot {s} quarantined",
                    site="decode", step=self._step_count, slot=s,
                    uid=req.uid))
                continue
            if tr is not None:
                tr.count("tokens_decoded", slot=s, uid=req.uid)
            self._emit(s, logits_by_slot[s], req)
        return True

    def run(self):
        """Drain all pending + active requests; returns the terminal
        Requests (``status`` 'done' or 'failed'). Never livelocks: a
        non-empty queue that can make no progress — every request
        inadmissible under its plan's HBM budget with no backoff pending —
        fails typed instead of spinning."""
        with obs.span("engine.run"):
            return self._run_inner()

    def _run_inner(self):
        while self.pending or any(r is not None for r in self.slot_req):
            progressed = self.step()
            if progressed:
                continue
            if not self.pending:
                break
            if any(r._ready_step > self._step_count for r in self.pending):
                continue      # backoff timers still counting down
            for req in list(self.pending):
                self.pending.remove(req)
                self._fail(None, req, AdmissionError(
                    f"request {req.uid} can never be admitted: "
                    f"{self._admission(req).describe()}"))
        return self.finished
