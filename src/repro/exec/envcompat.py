"""The single environment-variable compatibility module.

Every legacy process-global toggle maps onto one ExecutionPlan field here —
and ONLY here: ``os.environ`` is not read (or written) anywhere else under
``src/repro`` (ci.sh greps for it). Plans are built at *construction* time,
never at import, so flags exported after ``import repro...`` still take
effect (the old ``ops.KERNELS_ENABLED`` was read once at import and went
stale — the regression test for that lives in tests/test_exec_plan.py).

Recognized variables:

  REPRO_PLAN=<preset>              start from a named preset
                                   (default | oracle | interpret |
                                    triangle-oracle) — the ci.sh legs.
  REPRO_DISABLE_KERNELS=1          -> KernelPolicy.enabled = False
  REPRO_PALLAS_INTERPRET=1         -> KernelPolicy.interpret = True
  REPRO_FORCE_TRIANGLE_ORACLE=1    -> KernelPolicy.triangle = opm = "oracle"
  REPRO_FORCE_SCAN_ATTN_BWD=1      -> KernelPolicy.attn_bwd = "scan"
  REPRO_FAULT_SEED=<int>           -> default seed of resilience.FaultInjector
                                   (not a plan field; read via fault_seed())

Legacy flags layer on top of the preset, so e.g.
``REPRO_PLAN=interpret REPRO_FORCE_TRIANGLE_ORACLE=1`` composes.
"""
from __future__ import annotations

import dataclasses
import os

_ENV_VARS = (
    "REPRO_PLAN",
    "REPRO_DISABLE_KERNELS",
    "REPRO_PALLAS_INTERPRET",
    "REPRO_FORCE_TRIANGLE_ORACLE",
    "REPRO_FORCE_SCAN_ATTN_BWD",
)

# Memoized on the observed env values — re-reads the environment on every
# call (cheap), rebuilds the plan only when a relevant variable changed.
_cache: dict[tuple, object] = {}


def _flag(name: str) -> bool:
    return os.environ.get(name, "0") == "1"


def plan_from_env():
    """ExecutionPlan for the current process environment (see module doc)."""
    from repro.exec import plan as planmod

    key = tuple(os.environ.get(v) for v in _ENV_VARS)
    hit = _cache.get(key)
    if hit is not None:
        return hit

    p = planmod.preset(os.environ.get("REPRO_PLAN", "default"))
    kern = p.kernels
    if _flag("REPRO_DISABLE_KERNELS"):
        kern = dataclasses.replace(kern, enabled=False)
    if _flag("REPRO_PALLAS_INTERPRET"):
        kern = dataclasses.replace(kern, interpret=True)
    if _flag("REPRO_FORCE_TRIANGLE_ORACLE"):
        kern = dataclasses.replace(kern, triangle="oracle", opm="oracle")
    if _flag("REPRO_FORCE_SCAN_ATTN_BWD"):
        kern = dataclasses.replace(kern, attn_bwd="scan")
    if kern is not p.kernels:
        p = p.replace(kernels=kern)
    _cache[key] = p
    return p


def fault_seed() -> int | None:
    """Default FaultInjector seed from REPRO_FAULT_SEED (None when unset) —
    the resilience CI leg pins a process-wide fault schedule through here,
    keeping os.environ access confined to this module."""
    v = os.environ.get("REPRO_FAULT_SEED")
    return int(v) if v else None


def force_host_device_count(n: int) -> None:
    """Set the XLA host-platform device-count flag. Must run before jax
    initializes its backends — launchers (launch/dryrun.py, the benchmark
    subprocess scripts) call this instead of touching os.environ, keeping
    env access confined to this module. This package imports no jax, so
    importing it never triggers backend init."""
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
