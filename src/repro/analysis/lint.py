"""repro-lint: the AST half of the analysis gate (rule catalog in
``repro/analysis/__init__``).

Each rule has a stable ID (R001..R006) so suppressions and CI output survive
renames. Rules are *scoped by module path* (relative to ``src/repro``, with
"/" separators): an env read is a violation anywhere except the one compat
module, a bare ``except Exception:`` anywhere except the resilience package,
a wall-clock read only inside modules whose code runs under jit tracing, a
raw ``jnp.einsum`` only in the Evoformer/pair-stack modules that must route
hot paths through ``kernels/ops.py``.

Suppression syntax (checked on the flagged line and the line directly above,
so it works for both trailing comments and comment-above style)::

    o = jnp.einsum("bikc,bjkc->bijc", a, b_full)  # repro-lint: disable=R004

    # repro-lint: disable=R004 -- sanctioned materialized A/B fallback
    o = jnp.einsum(...)

A whole-file opt-out (``# repro-lint: disable-file=R003``) exists for
modules whose *job* is the suppressed behavior; prefer per-line
suppressions — they document exactly which statement is sanctioned and why.

This module is pure Python (no jax import): the lint leg of
``python -m repro.analysis`` runs before any backend initializes, and test
fixtures lint source strings directly via ``lint_source``.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Rule catalog
# ---------------------------------------------------------------------------

#: Module allowed to read/write the process environment (R001).
ENVCOMPAT_MODULE = "exec/envcompat.py"

#: Package allowed to catch bare ``Exception`` (R002): fault injection has to
#: interpose on arbitrary failures before re-dispatching them typed.
RESILIENCE_PREFIX = "resilience/"

#: Modules whose function bodies run under jit tracing (R003): a wall-clock
#: or host-RNG read there is either a silent constant (baked at trace time)
#: or a trace break — both bugs.
TRACED_PREFIXES = ("core/", "kernels/", "layers/", "models/", "memory/",
                   "optim/", "train/")

#: Evoformer / pair-stack modules whose hot paths must route through
#: ``kernels/ops.py`` (R004/R005). Sanctioned materialized A/B fallbacks
#: carry per-line suppressions with a rationale.
PAIR_STACK_MODULES = ("core/evoformer.py", "core/alphafold.py")

#: Scopes allowed to write to stdout/stderr directly (R006): the telemetry
#: package itself, the analysis/report tooling, CLI launcher entrypoints,
#: and any ``__main__`` module. Library code routes telemetry through the
#: obs event sink instead.
PRINT_EXEMPT_PREFIXES = ("obs/", "analysis/", "launch/")


@dataclass(frozen=True)
class Rule:
    id: str
    title: str
    rationale: str


RULES: dict[str, Rule] = {r.id: r for r in (
    Rule("R001", "env access outside exec/envcompat.py",
         "Every process-global toggle must map onto an ExecutionPlan field "
         "through the single compat module; a stray os.environ/os.getenv "
         "read (including aliased `from os import environ`) reintroduces "
         "import-order-dependent flags the plan system was built to kill."),
    Rule("R002", "bare `except Exception:` outside repro/resilience/",
         "Failure handling must dispatch on the typed fault hierarchy "
         "(resilience/errors.py); an anonymous catch-all can swallow "
         "injected faults and admission/deadline errors the serving "
         "engine's retry/degradation routing depends on seeing."),
    Rule("R003", "wall-clock or host-RNG call in traced code",
         "time.*/random.*/np.random/datetime.now inside a jit-traced module "
         "is baked to a constant at trace time (or breaks the trace); "
         "randomness must come from jax.random keys, timing from the host "
         "side of the step loop."),
    Rule("R004", "raw jnp.einsum in an Evoformer/pair-stack module",
         "Pair-stack contractions are the r^2-scale hot paths; they must "
         "route through kernels/ops.py (fused_attention / "
         "fused_triangle_mult / fused_outer_product_mean) so kernel-leg "
         "selection, AutoChunk tiling and the DAP sharding hooks apply. "
         "The sanctioned materialized A/B fallbacks carry per-line "
         "suppressions."),
    Rule("R005", "materialized softmax in an Evoformer/pair-stack module",
         "jax.nn.softmax materializes the (..., r, r) probs tensor; "
         "attention must go through ops.fused_attention (online softmax) "
         "or ops.fused_softmax (one-pass, unflattened under GSPMD)."),
    Rule("R006", "print()/ad-hoc stdout in a library module",
         "Library code under src/repro/ must not write to stdout/stderr "
         "directly — telemetry goes through the repro.obs event sink "
         "(structured, scoped, schema-validated) so long-running loops "
         "stay quiet and machine-readable. Exempt: obs/, analysis/, "
         "launch/ CLI entrypoints, and __main__ modules."),
)}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str        # path relative to the linted root, "/"-separated
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Z0-9,\s]+)")
_SUPPRESS_FILE_RE = re.compile(r"#\s*repro-lint:\s*disable-file=([A-Z0-9,\s]+)")


def _suppressed_rules(line_text: str) -> set[str]:
    m = _SUPPRESS_RE.search(line_text)
    if not m:
        return set()
    return {t.strip() for t in m.group(1).split(",") if t.strip()}


def _file_suppressions(src: str) -> set[str]:
    out: set[str] = set()
    for m in _SUPPRESS_FILE_RE.finditer(src):
        out |= {t.strip() for t in m.group(1).split(",") if t.strip()}
    return out


# ---------------------------------------------------------------------------
# The visitor
# ---------------------------------------------------------------------------

_TIME_FUNCS = None      # any call on the time module is wall-clock/sleep
_DATETIME_NOW = {"now", "utcnow", "today"}


class _Visitor(ast.NodeVisitor):
    def __init__(self, relpath: str):
        self.relpath = relpath
        self.findings: list[tuple[str, int, str]] = []
        # alias -> canonical module name, for modules we care about
        self.mod_alias: dict[str, str] = {}
        # names bound by `from os import environ as e` style imports
        self.env_names: set[str] = set()

        self.in_traced = relpath.startswith(TRACED_PREFIXES)
        self.in_pair_stack = relpath in PAIR_STACK_MODULES
        self.env_exempt = relpath == ENVCOMPAT_MODULE
        self.exception_exempt = relpath.startswith(RESILIENCE_PREFIX)
        self.print_exempt = (relpath.startswith(PRINT_EXEMPT_PREFIXES)
                             or relpath.endswith("__main__.py"))

    # -- helpers ----------------------------------------------------------

    def _flag(self, rule: str, node: ast.AST, message: str):
        self.findings.append(
            (rule, node.lineno, getattr(node, "end_lineno", node.lineno),
             message))

    def _root_module(self, node: ast.AST) -> str | None:
        """Canonical module of an attribute chain root: `np.random.rand`
        -> 'numpy', `os.environ` -> 'os', `jax.random.split` -> 'jax'."""
        while isinstance(node, ast.Attribute):
            node = node.value
        if isinstance(node, ast.Name):
            return self.mod_alias.get(node.id)
        return None

    def _attr_chain(self, node: ast.AST) -> list[str]:
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
        return parts[::-1]

    # -- imports ----------------------------------------------------------

    _TRACKED = {"os", "sys", "time", "random", "datetime", "numpy", "jax",
                "jax.numpy", "numpy.random"}

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            if a.name in self._TRACKED:
                self.mod_alias[a.asname or a.name.split(".")[0]] = a.name
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module == "os":
            for a in node.names:
                if a.name in ("environ", "environb", "getenv", "putenv",
                              "unsetenv"):
                    if not self.env_exempt:
                        self._flag("R001", node,
                                   f"`from os import {a.name}` aliases the "
                                   "process environment outside "
                                   f"{ENVCOMPAT_MODULE}")
                    self.env_names.add(a.asname or a.name)
        elif node.module in ("jax", "jax.numpy", "numpy"):
            for a in node.names:
                if a.name in ("numpy", "random"):
                    self.mod_alias[a.asname or a.name] = \
                        f"{node.module}.{a.name}"
        self.generic_visit(node)

    # -- R001: environment access -----------------------------------------

    def visit_Attribute(self, node: ast.Attribute):
        if not self.env_exempt and self._root_module(node) == "os":
            chain = self._attr_chain(node)
            if len(chain) >= 2 and chain[1] in ("environ", "environb"):
                self._flag("R001", node,
                           f"os.{chain[1]} access outside {ENVCOMPAT_MODULE}")
        self.generic_visit(node)

    # -- R002: bare except Exception --------------------------------------

    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        if not self.exception_exempt and node.name is None:
            t = node.type
            if t is None:
                self._flag("R002", node,
                           "bare `except:` swallows typed failures")
            elif (isinstance(t, ast.Name)
                  and t.id in ("Exception", "BaseException")):
                self._flag("R002", node,
                           f"bare `except {t.id}:` outside "
                           f"{RESILIENCE_PREFIX} — catch (or re-raise) the "
                           "typed hierarchy, or bind it (`as err`) and "
                           "re-dispatch")
        self.generic_visit(node)

    # -- calls: R001 (os.getenv), R003, R004, R005 ------------------------

    def visit_Call(self, node: ast.Call):
        func = node.func
        chain = self._attr_chain(func)
        root_mod = self._root_module(func) if chain else None

        # R001: os.getenv()/os.putenv() and aliased environ()/getenv()
        if not self.env_exempt:
            if root_mod == "os" and len(chain) >= 2 and chain[1] in (
                    "getenv", "putenv", "unsetenv"):
                self._flag("R001", node,
                           f"os.{chain[1]}() outside {ENVCOMPAT_MODULE}")
            elif (isinstance(func, ast.Name)
                  and func.id in self.env_names):
                self._flag("R001", node,
                           f"aliased env accessor `{func.id}()` outside "
                           f"{ENVCOMPAT_MODULE}")

        # R006: ad-hoc stdout in library modules — telemetry goes through
        # the obs event sink, not print()/sys.stdout.write.
        if not self.print_exempt:
            if isinstance(func, ast.Name) and func.id == "print":
                self._flag("R006", node,
                           "print() in a library module — emit through the "
                           "repro.obs event sink (or move output to a "
                           "__main__/launch entrypoint)")
            elif (root_mod == "sys" and len(chain) >= 3
                  and chain[1] in ("stdout", "stderr")
                  and chain[2] in ("write", "writelines")):
                self._flag("R006", node,
                           f"sys.{chain[1]}.{chain[2]}() in a library "
                           "module — emit through the repro.obs event sink")

        if self.in_traced:
            self._check_traced_call(node, chain, root_mod)
        if self.in_pair_stack:
            self._check_pair_stack_call(node, chain, root_mod)
        self.generic_visit(node)

    def _check_traced_call(self, node, chain, root_mod):
        # R003: wall clock / sleep — any call on the time module
        if root_mod == "time":
            self._flag("R003", node,
                       f"time.{chain[-1]}() in traced module (baked to a "
                       "trace-time constant under jit)")
        # R003: stdlib random (jax.random resolves to 'jax...' — allowed)
        elif root_mod == "random":
            self._flag("R003", node,
                       f"random.{chain[-1]}() in traced module — use "
                       "jax.random keys")
        # R003: numpy.random (np.random.* chains)
        elif root_mod == "numpy.random" or (
                root_mod == "numpy" and len(chain) >= 3
                and chain[1] == "random"):
            self._flag("R003", node,
                       "numpy.random call in traced module — use "
                       "jax.random keys")
        # R003: datetime.now()/utcnow()/today()
        elif root_mod == "datetime" and chain[-1] in _DATETIME_NOW:
            self._flag("R003", node,
                       f"datetime {chain[-1]}() in traced module")

    def _check_pair_stack_call(self, node, chain, root_mod):
        # R004: raw einsum (jnp.einsum / np.einsum / jax.numpy.einsum)
        if chain and chain[-1] == "einsum" and root_mod in (
                "jax", "jax.numpy", "numpy", "numpy.random"):
            self._flag("R004", node,
                       "raw einsum in a pair-stack module — route through "
                       "kernels/ops.py (or suppress a sanctioned "
                       "materialized A/B fallback)")
        # R005: materialized softmax (jax.nn.softmax / nn.softmax)
        if len(chain) >= 2 and chain[-1] == "softmax" and (
                root_mod == "jax" or chain[0] == "nn"):
            self._flag("R005", node,
                       "materialized softmax in a pair-stack module — use "
                       "ops.fused_attention / ops.fused_softmax")


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def lint_source(src: str, relpath: str) -> list[Finding]:
    """Lint one module's source. ``relpath`` is the path relative to the
    ``src/repro`` root ("/"-separated) — it decides which rules apply."""
    try:
        tree = ast.parse(src)
    except SyntaxError as err:
        return [Finding("R000", relpath, err.lineno or 0,
                        f"syntax error: {err.msg}")]
    v = _Visitor(relpath)
    v.visit(tree)
    if not v.findings:
        return []
    lines = src.splitlines()
    file_off = _file_suppressions(src)

    def suppressed(rule: str, lineno: int, end_lineno: int) -> bool:
        if rule in file_off:
            return True
        # Line above the flagged node, plus every line of the node itself
        # (a trailing comment on any continuation line of a multiline call
        # counts).
        for ln in range(lineno - 1, (end_lineno or lineno) + 1):
            if 1 <= ln <= len(lines) and rule in _suppressed_rules(
                    lines[ln - 1]):
                return True
        return False

    out: list[Finding] = []
    seen: set[tuple[str, int]] = set()  # nested chains (x.environ.get)
    for rule, line, end, msg in sorted(v.findings, key=lambda f: f[1]):
        if (rule, line) in seen or suppressed(rule, line, end):
            continue
        seen.add((rule, line))
        out.append(Finding(rule, relpath, line, msg))
    return out


def lint_tree(root: str | None = None) -> list[Finding]:
    """Lint every .py module under ``root`` (default: the installed
    ``src/repro`` tree this module lives in)."""
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    findings: list[Finding] = []
    for dirpath, _, files in os.walk(root):
        for f in sorted(files):
            if not f.endswith(".py"):
                continue
            path = os.path.join(dirpath, f)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, encoding="utf-8") as fh:
                findings.extend(lint_source(fh.read(), rel))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def render_report(findings: list[Finding]) -> str:
    if not findings:
        return "repro-lint: clean"
    by_rule: dict[str, int] = {}
    lines = [f.render() for f in findings]
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    summary = ", ".join(f"{k} x{v}" for k, v in sorted(by_rule.items()))
    lines.append(f"repro-lint: {len(findings)} finding(s) ({summary})")
    return "\n".join(lines)
