"""Fused triangle-multiplication + outer-product-mean kernel A/B at
pair-stack shapes (the post-attention Evoformer hot paths).

Two executions of each chain:

  fused         ops.fused_triangle_mult / ops.fused_outer_product_mean — the
                tile-bounded sweep (Pallas on TPU; off-TPU the XLA legs: the
                j-block scan for the triangle, the reassociated contraction
                for the OPM — no (B, r, r, c, c) tensor exists at all) with
                the recompute custom_vjp (inputs + per-tile stats + output).
  materialized  the same ops entry points inside a
                ``use_plan(... triangle='oracle', opm='oracle')`` scope —
                the pre-kernel jnp path (ref.triangle_mult_ref /
                ref.outer_product_mean_ref): the full (B, r, r, c) fp32
                product / (B, r, r, c, c) outer-product transient in HBM,
                autodiff backward storing them as residuals. Scoping the
                plan per variant (instead of flipping env vars) keeps the
                interleaved A/B cells leak-free.

For each shape: forward and forward+backward wall time plus the modeled
peak transient bytes (repro.memory.autochunk.triangle_transient_bytes /
opm_transient_bytes) — the fused columns scale with the planner tile, the
materialized columns with r²·c. Acceptance rows:
``tri_opm_fused_vs_materialized_{fwd,fwdbwd}_r{r}`` are the combined
pair-stack ratios. On the CPU XLA leg the forward ratio lands around the
0.6x gate (the OPM reassociation is the big win; both paths are otherwise
GEMM-flop-bound); the fwd+bwd ratio sits ~0.8x because the recompute
custom_vjp pays one extra product pass — that pass is exactly what bounds
the backward's transient at the tile instead of r²·c, which is the metric
the TPU target cares about (HBM traffic), shown in the bytes columns.
Interpret-mode Pallas runs only under REPRO_PALLAS_INTERPRET=1; the bytes
columns are backend-independent.
"""
import functools

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.exec.plan import current_plan, use_plan
from repro.kernels import ops
from repro.memory.autochunk import opm_transient_bytes, triangle_transient_bytes

TILE = 128

def _oracle_plan():
    """Materialized-variant plan: the AMBIENT plan at call time (not import
    time) with only the pair-stack ops pinned to their jnp oracles (the
    ci.sh "triangle-oracle" leg as a data value)."""
    return current_plan().with_kernels(triangle="oracle", opm="oracle")


def _materialized_tri(*args):
    with use_plan(_oracle_plan()):
        return ops.fused_triangle_mult(*args)


def _materialized_opm(*args):
    with use_plan(_oracle_plan()):
        return ops.fused_outer_product_mean(*args)


def _tri_inputs(r, c, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 10)
    shape = (1, r, r, c)
    a_lin = jax.random.normal(ks[0], shape)
    ga = jax.random.normal(ks[1], shape)
    mask = jax.random.bernoulli(ks[2], 0.9, (1, r, r)).astype(jnp.float32)
    b_full = jax.random.normal(ks[3], shape)
    gamma = jax.random.normal(ks[4], (c,))
    beta = jax.random.normal(ks[5], (c,))
    w_out = jax.random.normal(ks[6], (c, d))
    b_out = jax.random.normal(ks[7], (d,))
    g_lin = jax.random.normal(ks[8], (1, r, r, d))
    g_bias = jax.random.normal(ks[9], (d,))
    return (a_lin, ga, mask, b_full, gamma, beta, w_out, b_out, g_lin, g_bias)


def _opm_inputs(s, r, c, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    a = jax.random.normal(ks[0], (1, s, r, c))
    b = jax.random.normal(ks[1], (1, s, r, c))
    ma = jax.random.bernoulli(ks[2], 0.9, (1, s, r)).astype(jnp.float32)
    mb = jax.random.bernoulli(ks[3], 0.9, (1, s, r)).astype(jnp.float32)
    a = a * ma[..., None]
    b = b * mb[..., None]
    w = jax.random.normal(ks[4], (c * c, d))
    bias = jax.random.normal(ks[5], (d,))
    return (a, b, ma, mb, w, bias)


def _paired(fns, args, iters=5, warmup=2):
    """Interleaved A/B timing for drift-robust ratios on noisy hosts: each
    iteration times every variant back-to-back; per-variant medians are
    taken over iterations, so slow system phases hit all variants alike."""
    import time as _time

    samples = {name: [] for name in fns}
    for name, fn in fns.items():
        for _ in range(warmup):
            out = fn(*args)
        jax.block_until_ready(out)
    for _ in range(iters):
        for name, fn in fns.items():
            t0 = _time.perf_counter()
            jax.block_until_ready(fn(*args))
            samples[name].append((_time.perf_counter() - t0) * 1e6)
    med = {name: sorted(ts)[len(ts) // 2] for name, ts in samples.items()}
    return med


def _ab(tag, fused_fn, mat_fn, args, diff_idx, peak_fused, peak_mat,
        iters=5):
    """Time fwd and fwd+bwd for both variants (interleaved); returns
    {variant: (t_fwd, t_fwdbwd)}."""
    peaks = {"fused": peak_fused, "materialized": peak_mat}

    def grad_of(fn):
        return jax.jit(jax.grad(
            lambda *a: jnp.sum(fn(*a) ** 2), argnums=diff_idx))

    t_f = _paired({"fused": jax.jit(fused_fn),
                   "materialized": jax.jit(mat_fn)}, args, iters=iters)
    t_b = _paired({"fused": grad_of(fused_fn),
                   "materialized": grad_of(mat_fn)}, args, iters=iters)
    times = {}
    for name in ("fused", "materialized"):
        csv_row(f"{tag}_{name}_fwd", t_f[name],
                f"peak_pair_bytes={peaks[name]}")
        csv_row(f"{tag}_{name}_fwdbwd", t_b[name],
                f"peak_pair_bytes={peaks[name]}")
        times[name] = (t_f[name], t_b[name])
    return times


def run():
    backend = jax.default_backend()
    d = 128
    for r, c in [(128, 64), (256, 128)]:
        # --- triangle multiplicative update ---
        targs = _tri_inputs(r, c, d)
        t_times = _ab(
            f"tri_r{r}c{c}",
            functools.partial(ops.fused_triangle_mult, tile=TILE),
            _materialized_tri,
            targs, (0, 3, 8),
            triangle_transient_bytes(r, r, c, tile=TILE, fused=True,
                                     dtype_bytes=4),
            triangle_transient_bytes(r, r, c, fused=False, dtype_bytes=4))

        # --- outer-product-mean (AlphaFold c=32) ---
        s, c_opm = 32, 32
        oargs = _opm_inputs(s, r, c_opm, d)
        o_times = _ab(
            f"opm_r{r}",
            functools.partial(ops.fused_outer_product_mean, tile=TILE),
            _materialized_opm,
            oargs, (0, 1),
            opm_transient_bytes(r, r, s, c_opm, tile=TILE, fused=True,
                                dtype_bytes=4),
            opm_transient_bytes(r, r, s, c_opm, fused=False, dtype_bytes=4))

        for phase, k in (("fwd", 0), ("fwdbwd", 1)):
            ratio = ((t_times["fused"][k] + o_times["fused"][k])
                     / (t_times["materialized"][k]
                        + o_times["materialized"][k]))
            csv_row(f"tri_opm_fused_vs_materialized_{phase}_r{r}", 0,
                    f"ratio={ratio:.2f}x (backend={backend})")


if __name__ == "__main__":
    run()
