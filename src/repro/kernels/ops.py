"""Public, shape-polymorphic entry points for the Pallas kernels.

Each op:
  * reshapes arbitrary leading dims down to the kernel's canonical layout,
  * runs the Pallas kernel forward (interpret=True automatically on CPU — TPU
    is the *target*, CPU interpret mode is the validation vehicle),
  * carries a ``jax.custom_vjp`` whose backward is the analytic gradient in
    plain jnp (memory-bound element-wise math that XLA fuses; on TPU these
    could be promoted to Pallas backward kernels — forward fusion is where
    the paper's win is),
  * falls back to the pure-jnp oracle (ref.py) when the shape is outside the
    kernel envelope or kernels are globally disabled.

Toggle: set REPRO_DISABLE_KERNELS=1 (or flip ``KERNELS_ENABLED``) to force
oracle paths everywhere — used by A/B tests and by the production-mesh
dry-run, where XLA fuses these patterns natively.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fused_elementwise import (
    bias_dropout_add_pallas,
    bias_sigmoid_mul_pallas,
)
from repro.kernels.fused_softmax import fused_softmax_pallas
from repro.kernels.layer_norm import layer_norm_pallas

KERNELS_ENABLED = os.environ.get("REPRO_DISABLE_KERNELS", "0") != "1"

# Kernel envelope: last-dim sizes beyond this would blow the VMEM tile budget
# on the v5e target (ROW_TILE rows * C * 4 B fp32 + headroom in ~16 MB VMEM).
_MAX_SOFTMAX_C = 16384
_MAX_NORM_C = 32768


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# fused softmax
# ---------------------------------------------------------------------------


def _softmax_impl(scale, has_bias, has_mask, x, bias, mask):
    n, h, r, c = x.shape
    if not KERNELS_ENABLED or c > _MAX_SOFTMAX_C:
        return ref.softmax_ref(x, bias if has_bias else None,
                               mask if has_mask else None, scale)
    return fused_softmax_pallas(
        x, bias if has_bias else None, mask if has_mask else None,
        scale=scale, has_bias=has_bias, has_mask=has_mask,
        interpret=_interpret(),
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _softmax_op(scale, has_bias, has_mask, x, bias, mask):
    return _softmax_impl(scale, has_bias, has_mask, x, bias, mask)


def _softmax_fwd(scale, has_bias, has_mask, x, bias, mask):
    y = _softmax_impl(scale, has_bias, has_mask, x, bias, mask)
    return y, (y, None if bias is None else bias.shape,
               None if mask is None else mask.shape)


def _softmax_bwd(scale, has_bias, has_mask, res, g):
    y, bias_shape, mask_shape = res
    yf = y.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    dot = jnp.sum(gf * yf, axis=-1, keepdims=True)
    dlogits = yf * (gf - dot)  # grad wrt (scale*x + bias + mask)
    dx = (dlogits * scale).astype(y.dtype)
    dbias = None
    if has_bias:
        b = bias_shape[0]
        n = y.shape[0]
        dbias = dlogits.reshape((b, n // b) + dlogits.shape[1:]).sum(axis=1)
    dmask = None
    if has_mask:
        dmask = dlogits.sum(axis=(1, 2))
    return dx, dbias, dmask


_softmax_op.defvjp(_softmax_fwd, _softmax_bwd)


def fused_softmax(
    x: jax.Array,
    bias: jax.Array | None = None,
    mask: jax.Array | None = None,
    scale: float = 1.0,
) -> jax.Array:
    """softmax(scale*x + bias + mask) over the last axis.

    x: (..., H, R, C) — leading dims are flattened into N for the kernel.
    bias: (H, R, C) or (B, H, R, C), N % B == 0 (each bias batch element is
          shared by N/B consecutive rows), or None.
    mask: additive, shape (..., C) matching x's leading dims, or None.

    5D form (group attention, Evoformer): x (B, G, H, R, C) with bias
    (B, H, R, C) shared across G and mask (B, G, C). When the Pallas path is
    disabled (production dry-run), this form computes WITHOUT flattening —
    reshaping (B, G) together would merge two mesh-sharded dims and force
    GSPMD to all-gather the whole representation (§Perf alphafold iter 3).
    """
    if x.ndim == 5 and not (KERNELS_ENABLED and x.shape[-1] <= _MAX_SOFTMAX_C):
        acc = x.astype(jnp.float32) * scale
        if bias is not None:
            acc = acc + bias.astype(jnp.float32)[:, None]
        if mask is not None:
            acc = acc + mask.astype(jnp.float32)[:, :, None, None, :]
        return jax.nn.softmax(acc, axis=-1).astype(x.dtype)
    if x.ndim == 5:
        b, g, h, r, c = x.shape
        xb = x.reshape((b * g, h, r, c))
        mb = mask.reshape((-1, c)) if mask is not None else None
        out = _softmax_op(scale, bias is not None, mask is not None, xb,
                          bias, mb)
        return out.reshape(x.shape)
    *lead, h, r, c = x.shape
    if bias is not None and bias.ndim == 3:
        bias = bias[None]
    xb = x.reshape((-1, h, r, c))
    mb = mask.reshape((-1, c)) if mask is not None else None
    out = _softmax_op(scale, bias is not None, mask is not None, xb, bias, mb)
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# layer norm
# ---------------------------------------------------------------------------


def _ln_impl(eps, x, gamma, beta):
    c = x.shape[-1]
    if not KERNELS_ENABLED or c > _MAX_NORM_C:
        return ref.layer_norm_ref(x, gamma, beta, eps)
    return layer_norm_pallas(x, gamma, beta, eps=eps, interpret=_interpret())


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ln_op(eps, x, gamma, beta):
    return _ln_impl(eps, x, gamma, beta)


def _ln_fwd(eps, x, gamma, beta):
    return _ln_impl(eps, x, gamma, beta), (x, gamma)


def _ln_bwd(eps, res, g):
    x, gamma = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (xf - mean) * inv
    dgamma = jnp.sum(gf * xhat, axis=0)
    dbeta = jnp.sum(gf, axis=0)
    gg = gf * gamma.astype(jnp.float32)
    dx = inv * (
        gg
        - jnp.mean(gg, axis=-1, keepdims=True)
        - xhat * jnp.mean(gg * xhat, axis=-1, keepdims=True)
    )
    return dx.astype(x.dtype), dgamma.astype(gamma.dtype), dbeta.astype(gamma.dtype)


_ln_op.defvjp(_ln_fwd, _ln_bwd)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last axis; any leading shape."""
    c = x.shape[-1]
    xb = x.reshape((-1, c))
    return _ln_op(eps, xb, gamma, beta).reshape(x.shape)


# ---------------------------------------------------------------------------
# bias + sigmoid + mul (gating)
# ---------------------------------------------------------------------------


def _bsm_impl(g, bg, v):
    c = g.shape[-1]
    if not KERNELS_ENABLED or c > _MAX_NORM_C:
        return ref.bias_sigmoid_mul_ref(g, bg, v)
    return bias_sigmoid_mul_pallas(g, bg, v, interpret=_interpret())


@jax.custom_vjp
def _bsm_op(g, bg, v):
    return _bsm_impl(g, bg, v)


def _bsm_fwd(g, bg, v):
    return _bsm_impl(g, bg, v), (g, bg, v)


def _bsm_bwd(res, grad):
    g, bg, v = res
    gradf = grad.astype(jnp.float32)
    s = jax.nn.sigmoid(g.astype(jnp.float32) + bg.astype(jnp.float32))
    dv = (gradf * s).astype(v.dtype)
    dg_f = gradf * v.astype(jnp.float32) * s * (1.0 - s)
    dg = dg_f.astype(g.dtype)
    dbg = dg_f.sum(axis=0).astype(bg.dtype)
    return dg, dbg, dv


_bsm_op.defvjp(_bsm_fwd, _bsm_bwd)


def bias_sigmoid_mul(g: jax.Array, bg: jax.Array, v: jax.Array) -> jax.Array:
    """sigmoid(g + bg) * v; g and v share shape (..., C), bg is (C,)."""
    c = g.shape[-1]
    out = _bsm_op(g.reshape((-1, c)), bg, v.reshape((-1, c)))
    return out.reshape(v.shape)


# ---------------------------------------------------------------------------
# bias + dropout + add (residual)
# ---------------------------------------------------------------------------


def _bda_impl(rate, x, b, residual, keep):
    c = x.shape[-1]
    if not KERNELS_ENABLED or c > _MAX_NORM_C:
        return ref.bias_dropout_add_ref(x, b, residual,
                                        keep if rate > 0.0 else None, rate)
    return bias_dropout_add_pallas(x, b, residual, keep, rate=rate,
                                   interpret=_interpret())


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _bda_op(rate, x, b, residual, keep):
    return _bda_impl(rate, x, b, residual, keep)


def _bda_fwd(rate, x, b, residual, keep):
    return _bda_impl(rate, x, b, residual, keep), (keep,)


def _bda_bwd(rate, res, g):
    (keep,) = res
    gf = g.astype(jnp.float32)
    if rate > 0.0:
        dx_f = gf * keep / (1.0 - rate)
    else:
        dx_f = gf
    return (dx_f.astype(g.dtype), dx_f.sum(axis=0).astype(g.dtype), g,
            jnp.zeros_like(keep))


_bda_op.defvjp(_bda_fwd, _bda_bwd)


def bias_dropout_add(
    x: jax.Array,
    b: jax.Array,
    residual: jax.Array,
    rate: float = 0.0,
    rng: jax.Array | None = None,
) -> jax.Array:
    """residual + dropout(x + b, rate); rng=None or rate=0 disables dropout."""
    c = x.shape[-1]
    xb = x.reshape((-1, c))
    rb = residual.reshape((-1, c))
    if rng is not None and rate > 0.0:
        keep = jax.random.bernoulli(rng, 1.0 - rate, xb.shape).astype(jnp.float32)
        eff_rate = rate
    else:
        keep = jnp.ones_like(xb, dtype=jnp.float32)
        eff_rate = 0.0
    out = _bda_op(eff_rate, xb, b, rb, keep)
    return out.reshape(residual.shape)
