"""Serve a reduced assigned-architecture LM with batched requests.

  PYTHONPATH=src python examples/serve_llm.py --arch qwen2-1.5b --requests 8
  PYTHONPATH=src python examples/serve_llm.py --chaos --fault-seed 7

Demonstrates continuous batching (more requests than slots), per-request
sampling temperature, and EOS handling, on any of the 10 assigned archs.

Failure handling (see repro/resilience/__init__.py for the full matrix):
the engine serves every request to a *typed* terminal state — no failure
mode hangs the batch or silently drops tokens.

  * Admission control: ``submit`` raises ``AdmissionError`` (typed
    backpressure) for over-length prompts, a full pending queue
    (``max_pending``), or a (plan, length) that exceeds the
    ``check_decoder_admission`` HBM model under the request plan's budget.
  * Deadlines: ``submit(..., deadline=N)`` fails the request with
    ``DeadlineExceeded`` after N engine steps, queued or active.
  * Retry: ``submit(..., retry=RetryPolicy(...))`` requeues transient
    failures with capped exponential backoff (in engine steps); the retry
    re-prefills from scratch, so tokens are never lost or duplicated.
  * Non-finite quarantine: a per-slot in-trace guard fails only the slot
    whose logits went non-finite — the rest of the batch is untouched.
  * Graceful degradation: on OOM the request walks ``plan.degrade()``
    (tighter MemoryPolicy chunks -> oracle kernel leg), recording each
    rung on ``Request.fallback_chain``.

``--chaos`` drives all of this live: it wraps the run in a seeded
``inject_faults`` scope with a mixed fault schedule and prints each
request's terminal status, attempts, and fallback chain.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models.decoder import init_model
from repro.resilience import FaultSpec, RetryPolicy, inject_faults
from repro.serving.engine import ServingEngine


def chaos_specs():
    """A mixed schedule: one transient decode blip (retried), one OOM
    (degraded down the plan ladder), one NaN poisoning (quarantined +
    retried)."""
    return [
        FaultSpec("transient", "decode", uid=1, times=1),
        FaultSpec("oom", "decode", uid=2, times=1),
        FaultSpec("nonfinite", "decode", uid=3, times=1),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--chaos", action="store_true",
                    help="inject a deterministic fault schedule and show "
                         "retry / quarantine / degradation handling")
    ap.add_argument("--fault-seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced_variant=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(params, cfg, n_slots=args.slots, max_seq=128)

    retry = RetryPolicy(
        max_attempts=3, backoff=1.0,
        retryable=lambda e: not isinstance(e, (ValueError, TypeError)),
    ) if args.chaos else None

    rng = np.random.default_rng(0)
    t0 = time.time()
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=(4 + rng.integers(0, 12),))
        reqs.append(engine.submit(prompt, max_new_tokens=args.max_new,
                                  temperature=args.temperature, retry=retry))
    if args.chaos:
        with inject_faults(*chaos_specs(), seed=args.fault_seed) as inj:
            finished = engine.run()
        print(f"chaos: injected {inj.total_fired} faults: {inj.counts}")
    else:
        finished = engine.run()
    dt = time.time() - t0
    total_toks = sum(len(r.generated) for r in finished)
    print(f"arch={args.arch} served {len(finished)} requests, "
          f"{total_toks} tokens in {dt:.2f}s "
          f"({total_toks / dt:.1f} tok/s on {args.slots} slots)")
    for r in finished[: 8 if args.chaos else 4]:
        line = (f"  req {r.uid}: prompt[{len(r.prompt)}] "
                f"status={r.status} -> {r.generated}")
        if r.attempts > 1:
            line += f" (attempts={r.attempts})"
        if r.fallback_chain:
            line += f" (degraded {len(r.fallback_chain)}x)"
        if r.error is not None:
            line += f" [{type(r.error).__name__}]"
        print(line)


if __name__ == "__main__":
    main()
