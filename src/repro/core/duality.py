"""Duality Async Operation — TPU/XLA adaptation (paper §IV.C, Fig. 7).

In PyTorch the paper needs a *pair* of autograd ops (trigger / block) because a
dynamic-graph framework cannot otherwise express "launch this collective now,
consume it later, and mirror that in backward". In XLA's static graph the same
contract is expressed structurally:

  1. *Code placement*: the Evoformer block launches the MSA swap-back
     all_to_all immediately after Outer-Product-Mean consumes the r-sharded
     MSA, and consumes the result only at the next block's row attention — the
     entire pair stack sits between launch and use (core/evoformer.py). The
     gathers for pair-bias / triangular operands are likewise launched before
     the independent QKV projections that separate them from their consumers.
  2. *Scheduler*: XLA:TPU's latency-hiding scheduler turns collectives with
     independent compute between def and use into ``*-start``/``*-done`` pairs
     that run on the communication core while the MXU keeps working — the
     machine analogue of the paper's comm/compute streams. Reverse-mode AD
     differentiates all_to_all -> all_to_all and all_gather ->
     reduce_scatter, so the backward overlap mirrors forward placement, which
     is exactly the "duality" the paper engineers by hand.

This module provides the explicit helper (an optimization-barrier-fenced
launch window) plus the HLO verifier used by benchmarks/EXPERIMENTS.md to
certify that independent compute separates a collective from its first use.

``overlap_window`` is wired at the Evoformer launch sites (core/evoformer.py):
the MSA swap-back all_to_all is fenced with the completed pair stack at block
end, the gathered pair bias with the QKV projections, and the OPM/triangular
gather operands with their independent left projections — so the scheduler
cannot sink those collectives to their consumers past the overlap-eligible
compute. tests/test_distributed.py lowers a 2-block stack and checks
``overlap_report`` on the scheduled HLO.
"""
from __future__ import annotations

import re

import jax

from repro.exec.plan import current_plan


@jax.custom_vjp
def _overlap_window_op(comm_result, independent_result):
    return jax.lax.optimization_barrier((comm_result, independent_result))


def _overlap_window_fwd(comm_result, independent_result):
    return _overlap_window_op(comm_result, independent_result), None


def _overlap_window_bwd(_, g):
    return jax.lax.optimization_barrier(g)


_overlap_window_op.defvjp(_overlap_window_fwd, _overlap_window_bwd)


def overlap_window(comm_result, independent_result):
    """Fence `independent_result` as not-reorderable *past* the communication:
    returns both, tied through an optimization barrier so the scheduler keeps
    the independent compute inside the launch->use window rather than sinking
    it below the consumer. A no-op numerically.

    Differentiable by construction (optimization_barrier has no AD rule):
    the backward barriers the *cotangents* the same way — reverse-mode AD
    turns the forward collective into its dual collective, and the mirrored
    fence keeps the dual's launch->use window, which is exactly the paper's
    forward/backward duality.

    Gated by the ExecutionPlan's AsyncPolicy: with
    ``plan.duality.overlap_windows == False`` this is a plain passthrough
    (no barrier at all, forward or backward), so Duality-Async A/B cells are
    a ``use_plan`` scope instead of code edits."""
    if not current_plan().duality.overlap_windows:
        return comm_result, independent_result
    return _overlap_window_op(comm_result, independent_result)


_COLLECTIVES = ("all-to-all", "all-gather", "all-reduce", "reduce-scatter",
                "collective-permute")
_COMPUTE_OPS = ("dot", "convolution", "fusion", "custom-call")


def overlap_report(hlo_text: str) -> dict:
    """Scan scheduled/optimized HLO for async collective start/done pairs and
    count compute ops between them. Returns per-collective stats; used by the
    Duality-Async benchmark to certify the overlap window is non-empty."""
    lines = hlo_text.splitlines()
    starts: dict[str, int] = {}
    report = {"pairs": 0, "pairs_with_compute_between": 0, "sync_collectives": 0}
    for i, ln in enumerate(lines):
        m = re.search(r"%?([\w.\-]+)\s*=.*?(" + "|".join(_COLLECTIVES) + r")-start",
                      ln)
        if m:
            starts[m.group(1)] = i
            continue
        m = re.search(r"(" + "|".join(_COLLECTIVES) + r")-done\(([^)]*)\)", ln)
        if m:
            # find matching start by operand name
            operand = m.group(2).strip().lstrip("%")
            if operand in starts:
                report["pairs"] += 1
                window = lines[starts[operand] + 1 : i]
                if any(any(op in w for op in _COMPUTE_OPS) for w in window):
                    report["pairs_with_compute_between"] += 1
            continue
        if any(re.search(rf"= .*{c}\(", ln) for c in _COLLECTIVES):
            report["sync_collectives"] += 1
    return report
