"""Hypothesis import shim: property tests degrade to skips when hypothesis is
not installed, instead of erroring the whole module at collection.

Usage (replaces ``from hypothesis import given, settings, strategies as st``):

    from hypothesis_compat import given, settings, st

With hypothesis present this re-exports the real API unchanged. Without it,
``@given(...)`` marks the test skipped, ``@settings(...)`` is a no-op, and
``st.<anything>(...)`` returns inert placeholders so module-level strategy
expressions still evaluate — every non-property test in the module keeps
collecting and running.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised where hypothesis is absent
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """st.integers(...), st.floats(...), ... -> inert placeholder."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
