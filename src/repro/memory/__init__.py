"""Memory planning subsystem: the AutoChunk activation-memory planner."""
from repro.memory.autochunk import (  # noqa: F401
    ChunkPlan,
    apply_plan,
    attention_transient_bytes,
    evoformer_peak_bytes,
    plan_decoder_blocks,
    plan_evoformer_chunks,
    resolve_evoformer_config,
)
