"""Paper Table III — communication volume per Evoformer block, DAP vs TP.

Analytic volumes for the paper's training shapes, plus *measured* collective
schedules parsed from the compiled HLO of both implementations (subprocess on
4 fake host devices).
"""
import os
import subprocess
import sys

from benchmarks.common import csv_row

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MEASURE = r"""
import re, jax, jax.numpy as jnp
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, evoformer_stack
from repro.core.dap import dap_evoformer_stack, shard_dap_inputs
from repro.core.tp import tp_evoformer_stack
from repro.roofline import analysis
cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2, head_dim=8,
                      opm_dim=8, tri_mult_dim=16, n_blocks=1)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B,s,r = 1,8,16
msa = jax.random.normal(jax.random.PRNGKey(1),(B,s,r,cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2),(B,r,r,cfg.d_pair))
masks = (jnp.ones((B,s,r)), jnp.ones((B,r)), jnp.ones((B,r,r)))
mesh2 = jax.make_mesh((1,2), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
mesh4 = jax.make_mesh((1,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
# DAP fwd
fn = jax.jit(dap_evoformer_stack(mesh4, cfg, remat=False))
args = shard_dap_inputs(mesh4, msa, pair, *masks)
txt = fn.lower(params, *args).compile().as_text()
st = analysis.parse_collectives(txt, 4)
print("DAP_FWD", {k: int(v) for k, v in st.counts.items()},
      int(sum(st.payload_bytes.values())))
# TP fwd (2-way: pair heads = 2)
fn = jax.jit(tp_evoformer_stack(mesh2, cfg, remat=False))
txt = fn.lower(params, msa, pair, *masks).compile().as_text()
st = analysis.parse_collectives(txt, 2)
print("TP_FWD", {k: int(v) for k, v in st.counts.items()},
      int(sum(st.payload_bytes.values())))
"""


def analytic(n_r, n_s, h_m=256, h_z=128, n_dev=4, bf=2):
    """Paper Table III volumes (forward), bytes per device."""
    msa = n_s * n_r * h_m * bf
    pair = n_r * n_r * h_z * bf
    # TP: 6 AllReduce of full activations (ring: 2x payload)
    tp = 6 * 2 * (4 * msa + 2 * pair) / 6  # avg of msa/pair module payloads
    tp = 2 * (3 * msa + 3 * pair)          # 3 msa-sized + 3 pair-sized
    # DAP: 2 msa a2a (1/N of local shard moves) + 3 pair a2a + gathers
    a2a = (2 * msa + 3 * pair) / n_dev * (n_dev - 1) / n_dev
    gathers = (pair / h_z * 8          # msa-row bias (H_m heads -> 8)
               + n_s * n_r * 32 * bf   # OPM right proj (c=32)
               + 2 * n_r * n_r * 128 * bf  # tri-mult right (c=128)
               + 2 * pair / h_z * 4)   # 2 tri-attn biases (H_z heads -> 4)
    dap = a2a + gathers * (n_dev - 1) / n_dev
    return tp, dap


def run():
    for name, (n_r, n_s) in (("initial", (256, 128)), ("finetune", (384, 512))):
        tp, dap = analytic(n_r, n_s)
        csv_row(f"commvol_{name}_TP_fwd_bytes", tp,
                f"analytic per-device, paper: 12xAllReduce/blk (6 fwd)")
        csv_row(f"commvol_{name}_DAP_fwd_bytes", dap,
                f"analytic per-device, ratio TP/DAP={tp / dap:.2f}x")

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", MEASURE], env=env,
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        csv_row("commvol_measured", 0, "FAILED: " + out.stderr[-200:])
        return
    for line in out.stdout.strip().splitlines():
        tag, rest = line.split(" ", 1)
        csv_row(f"commvol_measured_{tag}", 0, rest.replace(",", ";"))


if __name__ == "__main__":
    run()
