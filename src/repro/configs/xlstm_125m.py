"""xLSTM-125M [arXiv:2405.04517]: sLSTM + mLSTM blocks (no separate FFN,
d_ff=0); attention-free => paper's axis-swap DAP inapplicable (DESIGN.md)."""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", source="arXiv:2405.04517",
    n_layers=12, d_model=768, n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    subquadratic=True,
    stages=(("mlstm", 5), ("slstm", 1), ("mlstm", 5), ("slstm", 1)),
)
REDUCED = reduced(CONFIG, stages=(("mlstm", 1), ("slstm", 1)))
