"""Resilience layer: deterministic fault injection, retry policies, and the
typed failure vocabulary of the serving / training stack.

At production scale (the ROADMAP's millions-of-users folding service, and
ParaFold's large-scale prediction runs) the binding constraint is not peak
throughput but surviving stragglers, OOMs, and stage failures without losing
work. This package makes every failure path a first-class, deterministically
testable object:

  * ``faults``  — ``FaultInjector`` / ``FaultSpec`` / ``inject_faults``: a
    seedable, ``use_plan``-style scoped injector that fires typed faults at
    named sites on step/slot/uid predicates. No sleeps, no flakiness: the
    same specs + seed fire the same faults in the same order.
  * ``retry``   — ``RetryPolicy``: capped exponential backoff with optional
    deterministic jitter and a pluggable ``retryable`` predicate.
  * ``errors``  — the typed failure vocabulary shared by the serving engine
    and checkpointing (``AdmissionError``, ``DeadlineExceeded``,
    ``CorruptCheckpointError``).

Fault-site / retry / degradation matrix (how each fault at each site is
handled by the serving engine and checkpointing):

  site             fault                 handling
  ---------------  --------------------  -----------------------------------
  prefill          OomFault /            graceful-degradation ladder:
                   RESOURCE_EXHAUSTED    retry under ``ExecutionPlan
                                         .degrade()`` rungs (tighter
                                         MemoryPolicy chunks -> oracle
                                         kernel leg), fallback chain
                                         recorded on the Request; typed
                                         fail when the ladder is exhausted.
  prefill          TransientDecodeFault  ``submit(..., retry=RetryPolicy)``:
                   / StageTimeout        slot-safe requeue with capped
                                         exponential backoff (in engine
                                         steps), typed fail when attempts
                                         are exhausted or no policy is set.
  decode           OomFault /            degradation ladder (as above); the
                   RESOURCE_EXHAUSTED    slot is torn down through the same
                                         ``_release`` invariant and the
                                         request re-prefills from scratch
                                         (no lost or duplicated tokens).
  decode           TransientDecodeFault  retry policy (as above).
                   / StageTimeout
  decode           NonFiniteFault        the injector poisons the slot's KV
                                         rows with NaN; the engine's
                                         in-trace per-decode-group guard
                                         quarantines ONLY the offending
                                         slots (other slots' caches stay
                                         bit-identical); quarantined
                                         requests fail typed, or retry when
                                         the policy marks NonFiniteFault
                                         retryable (the re-prefill
                                         overwrites the poisoned rows).
  (any)            deadline              ``submit(..., deadline=N)``: the
                                         request fails ``DeadlineExceeded``
                                         after N engine steps, queued or
                                         active.
  checkpoint.save  any fault             simulates a writer crash mid-write:
                                         the temp file is truncated and the
                                         fault raised BEFORE the atomic
                                         publish — the previous checkpoint
                                         stays intact, ``latest_checkpoint``
                                         skips + GCs the debris.

Training-side, ``train/loop.py`` carries a non-finite gradient guard
(skip-step + counter) that is a bit-identical no-op on healthy steps —
see ``make_train_step(guard_nonfinite=...)``.

This package imports no jax: scoping works before backends initialize, and
the injector is usable from launchers and subprocess scripts.
"""
from repro.resilience.errors import (  # noqa: F401
    AdmissionError,
    CorruptCheckpointError,
    DeadlineExceeded,
)
from repro.resilience.faults import (  # noqa: F401
    FaultInjector,
    FaultSpec,
    InjectedFault,
    NonFiniteFault,
    OomFault,
    StageTimeout,
    TransientDecodeFault,
    current_injector,
    fire,
    inject_faults,
    is_oom,
)
from repro.resilience.retry import RetryPolicy  # noqa: F401
