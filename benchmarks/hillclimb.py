"""§Perf hillclimb driver: run named optimization variants of the three chosen
(arch x shape) pairs through the dry-run and print before/after roofline terms.

  PYTHONPATH=src python -m benchmarks.hillclimb --pair yi_train
  PYTHONPATH=src python -m benchmarks.hillclimb --all --out hillclimb.json

Each variant is a hypothesis from EXPERIMENTS.md §Perf; the log there records
predicted vs measured deltas.
"""
import argparse
import json
import subprocess
import sys
import os

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# (pair name) -> (arch, shape, [(variant_name, overrides dict)])
PAIRS = {
    # 1. worst roofline fraction (memory-dominated dense train)
    "yi_train": ("yi-9b", "train_4k", [
        ("baseline", {}),
        ("qfull", {"attn_q_block": 0}),
        ("qfull_gatherkv", {"attn_q_block": 0, "gather_kv": True}),
        ("qfull_gatherkv_kv4k", {"attn_q_block": 0, "gather_kv": True,
                                 "attn_kv_block": 4096}),
    ]),
    # 2. most collective-bound (decode against a sharded cache)
    "qwen2_decode": ("qwen2-1.5b", "decode_32k", [
        ("baseline", {}),
        ("int8kv", {"kv_cache_int8": True}),
    ]),
    # 3. most representative of the paper's technique (MoE+MLA: EP all_to_all
    #    + DAP sequence sharding; the deepseek train step is where expert
    #    dispatch, MLA gathers and DAP interact)
    "deepseek_train": ("deepseek-v2-236b", "train_4k", [
        ("baseline", {}),
        ("qfull_gatherkv", {"attn_q_block": 0, "gather_kv": True}),
        ("qfull_gatherkv_bf16opt", {"attn_q_block": 0, "gather_kv": True,
                                    "opt_state_bf16": True}),
    ]),
    # memory-fit extensions for the two baseline non-fits (beyond the 3
    # hillclimb pairs — recorded in EXPERIMENTS.md §Perf as fit fixes)
    "qwen15_decode_fit": ("qwen1.5-32b", "decode_32k", [
        ("baseline", {}),
        ("int8kv", {"kv_cache_int8": True}),
    ]),
    # second-round variants (hypotheses from round 1 — see EXPERIMENTS §Perf)
    "qwen2_decode_r2": ("qwen2-1.5b", "decode_32k", [
        ("int8kv_repparams", {"kv_cache_int8": True,
                              "serve_replicate_params": True}),
    ]),
    # the paper's own model: remat-policy trade (recompute vs memory)
    "alphafold_ft": ("alphafold-finetune", "train", [
        ("baseline", {}),
        ("remat_dots", {"remat_policy": "dots"}),
    ]),
    # round 3: MLA keeps its latent (no materialized-KV gather) + bf16 moments
    "deepseek_train_r3": ("deepseek-v2-236b", "train_4k", [
        ("bf16opt", {"opt_state_bf16": True}),
    ]),
    # alphafold round 2: chunked Outer-Product-Mean (j-chunks of 64)
    "alphafold_ft_r2": ("alphafold-finetune", "train", [
        ("opm_chunk64", {"opm_chunk": 64}),
    ]),
    # measure the now-default flash/SWA custom VJPs on the windowed dense arch
    # (baseline = pre-VJP numbers in dryrun_single_pod.json)
    "gemma3_train_vjp": ("gemma3-27b", "train_4k", [
        ("flash_swa_vjp_defaults", {}),
    ]),
}

RUN_ONE = r"""
# dryrun sets the 512-device XLA flag (via exec/envcompat) before jax init;
# the materialized-path baseline runs under a use_plan("oracle") scope.
import json, sys
from repro.launch import dryrun
from repro.exec.plan import preset, use_plan
with use_plan(preset("oracle")):
    rec = dryrun.run_one({arch!r}, {shape!r}, overrides={overrides!r})
print("JSON::" + json.dumps(rec))
"""


def run_variant(arch, shape, overrides):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c",
         RUN_ONE.format(arch=arch, shape=shape, overrides=overrides)],
        env=env, capture_output=True, text=True, timeout=3600)
    for ln in out.stdout.splitlines():
        if ln.startswith("JSON::"):
            return json.loads(ln[6:])
    return {"status": "error", "error": out.stderr[-500:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(PAIRS), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    names = list(PAIRS) if args.all else [args.pair]
    results = {}
    for name in names:
        arch, shape, variants = PAIRS[name]
        results[name] = []
        base = None
        for vname, ov in variants:
            rec = run_variant(arch, shape, ov)
            rec["variant"] = vname
            results[name].append(rec)
            if rec["status"] != "ok":
                print(f"{name}/{vname}: {rec['status']} "
                      f"{rec.get('error','')[:200]}", flush=True)
                continue
            r = rec["roofline"]
            if vname == "baseline":
                base = r
            delta = ""
            if base is not None and vname != "baseline":
                dom = base["bottleneck"]
                key = {"compute": "t_compute_s", "memory": "t_memory_s",
                       "collective": "t_collective_s"}[dom]
                delta = (f" | dominant({dom}) {base[key]:.3g} -> {r[key]:.3g} "
                         f"({(1 - r[key] / base[key]) * 100:+.1f}%)")
            print(f"{name}/{vname}: tc={r['t_compute_s']:.3g} "
                  f"tm={r['t_memory_s']:.3g} tx={r['t_collective_s']:.3g} "
                  f"bneck={r['bottleneck']}"
                  f" mem={rec['memory']['per_device_bytes']/2**30:.2f}GB"
                  f" fits={rec['memory']['fits_16GB']}{delta}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
