"""Paper Fig. 10 — model-parallel scaling, DAP vs TP.

Measures real wall-clock of one Evoformer-stack forward+backward on 1/2/4
host devices (reduced config — CPU wall time gives *relative* scaling, the
quantity Fig. 10 plots). DAP runs at every degree; TP is capped at
pair_heads=2 for this config, reproducing the paper's TP scaling limit.
"""
import os
import subprocess
import sys

from benchmarks.common import csv_row

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import time, jax, jax.numpy as jnp
NDEV = {ndev}
MODE = "{mode}"
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, evoformer_stack
from repro.core.dap import dap_evoformer_stack, shard_dap_inputs
from repro.core.tp import tp_evoformer_stack
cfg = EvoformerConfig(d_msa=64, d_pair=32, msa_heads=4, pair_heads=2, head_dim=16,
                      opm_dim=16, tri_mult_dim=32, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B,s,r = 1,16,32
msa = jax.random.normal(jax.random.PRNGKey(1),(B,s,r,cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2),(B,r,r,cfg.d_pair))
masks = (jnp.ones((B,s,r)), jnp.ones((B,r)), jnp.ones((B,r,r)))
if MODE == "local":
    fwd = lambda p, *a: evoformer_stack(p, *a, cfg=cfg, remat=False)
    args = (msa, pair) + masks
else:
    mesh = jax.make_mesh((1, NDEV), ("data","model"),
                         axis_types=(jax.sharding.AxisType.Auto,)*2)
    if MODE == "dap":
        fwd = dap_evoformer_stack(mesh, cfg, remat=False)
        args = shard_dap_inputs(mesh, msa, pair, *masks)
    else:
        fwd = tp_evoformer_stack(mesh, cfg, remat=False)
        args = (msa, pair) + masks
def loss(p, *a):
    m, z = fwd(p, *a)
    return jnp.sum(m**2) + jnp.sum(z**2)
step = jax.jit(jax.grad(loss))
out = step(params, *args); jax.block_until_ready(out)
ts = []
for _ in range(6):
    t0 = time.perf_counter()
    out = step(params, *args); jax.block_until_ready(out)
    ts.append(time.perf_counter()-t0)
ts.sort()
print("TIME_US", ts[len(ts)//2]*1e6)
"""


def measure(mode: str, ndev: int) -> float:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(ndev=ndev, mode=mode)],
        env=env, capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        return float("nan")
    for ln in out.stdout.splitlines():
        if ln.startswith("TIME_US"):
            return float(ln.split()[1])
    return float("nan")


def run():
    base = measure("local", 1)
    csv_row("mp_scaling_1dev_baseline", base, "single device fwd+bwd")
    for ndev in (2, 4):
        t = measure("dap", ndev)
        eff = base / (t * ndev) if t == t else 0.0
        csv_row(f"mp_scaling_DAP_{ndev}dev", t,
                f"parallel_efficiency={eff:.2f}")
    t = measure("tp", 2)
    eff = base / (t * 2) if t == t else 0.0
    csv_row("mp_scaling_TP_2dev", t,
            f"parallel_efficiency={eff:.2f} (TP capped at pair heads)")


if __name__ == "__main__":
    run()
