#!/usr/bin/env bash
# Tier-1 CI, five legs:
#   1. default          — Pallas kernels enabled; on CPU each op runs its
#                         XLA-native leg (fused attention = online-softmax
#                         scan, fused triangle/OPM = j-block scans), on TPU
#                         the Pallas kernels.
#   2. kernels disabled — REPRO_DISABLE_KERNELS=1: pure-jnp oracles, the
#                         scores-materialized attention, and the
#                         materialized pair-stack paths (A/B legs).
#   3. kernel validation— REPRO_PALLAS_INTERPRET=1: the Pallas kernels
#                         (fwd + the fused attention backward + the fused
#                         triangle/OPM forwards) execute in interpret mode
#                         on the kernel test modules.
#   4. triangle oracle  — REPRO_FORCE_TRIANGLE_ORACLE=1: tier-1 with ONLY
#                         the new pair-stack kernels pinned to their jnp
#                         oracles (the rest of the kernel set stays on its
#                         default legs) — isolates regressions to the
#                         triangle/OPM fusion itself.
#   5. multi-device     — 8 host devices: distributed DAP/GSPMD parity, the
#                         shard-mapped fused attention + triangle/OPM, and
#                         the fused attention suite, on both kernel legs.
# Any divergence between a kernel and its oracle fails fast in legs 1/3;
# legs 2/4 prove the fallback paths stay healthy on their own.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 leg 1/5: kernels ENABLED (XLA-native legs off-TPU) ==="
python -m pytest -x -q "$@"

echo "=== tier-1 leg 2/5: kernels DISABLED (REPRO_DISABLE_KERNELS=1, oracle paths) ==="
REPRO_DISABLE_KERNELS=1 python -m pytest -x -q "$@"

if [ "$#" -gt 0 ]; then
    # Scoped developer run: legs 3-5 run fixed module lists that would ignore
    # the selection — stop here rather than silently dropping the arguments.
    echo "ci.sh: args given — scoped run, legs 1-2 only"
    exit 0
fi

echo "=== tier-1 leg 3/5: Pallas interpret validation (REPRO_PALLAS_INTERPRET=1) ==="
REPRO_PALLAS_INTERPRET=1 python -m pytest -x -q \
    tests/test_kernels.py tests/test_fused_attention.py tests/test_triangle.py

echo "=== tier-1 leg 4/5: triangle/OPM kernels forced to oracle (REPRO_FORCE_TRIANGLE_ORACLE=1) ==="
REPRO_FORCE_TRIANGLE_ORACLE=1 python -m pytest -x -q \
    tests/test_triangle.py tests/test_evoformer.py tests/test_fused_attention.py \
    tests/test_autochunk.py tests/test_alphafold.py

echo "=== tier-1 leg 5/5: multi-device (8 host devices), both kernel legs ==="
XLA_FLAGS="--xla_force_host_platform_device_count=8" python -m pytest -x -q \
    tests/test_distributed.py tests/test_fused_attention.py tests/test_triangle.py
XLA_FLAGS="--xla_force_host_platform_device_count=8" REPRO_DISABLE_KERNELS=1 \
    python -m pytest -x -q tests/test_distributed.py

echo "ci.sh: all legs green"
