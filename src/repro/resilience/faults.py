"""Deterministic, seedable fault injection.

``FaultInjector`` is scoped exactly like ``exec.plan.use_plan`` — a
contextvar entered with ``inject_faults(...)`` — so fault scopes nest,
restore on exit, and compose with plan scopes without touching either's
hashing. Sites call the module-level ``fire(site, **ctx)``, which is a
no-op (returns ``()``) when no injector is active: production code paths
carry zero overhead and zero behavior change outside a fault scope.

    from repro.resilience import FaultSpec, inject_faults

    with inject_faults(FaultSpec("oom", "decode", uid=3, times=2),
                       FaultSpec("transient", "decode", p=0.1),
                       seed=1234) as inj:
        engine.run()
    assert inj.counts["OomFault"] == 2

Determinism contract: given the same specs, the same seed, and the same
sequence of ``fire`` calls (the engine's control flow is deterministic),
the same faults fire at the same events — tests never sleep and never
flake. Probabilistic specs (``p < 1``) draw from one seeded stream in call
order; everything else is pure predicate matching.

The default seed comes from ``REPRO_FAULT_SEED`` through the single
env-compat module (``exec/envcompat.fault_seed``), so CI legs can pin a
process-wide schedule while environment access stays confined there.
"""
from __future__ import annotations

import random
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


# ---------------------------------------------------------------------------
# Typed faults
# ---------------------------------------------------------------------------


class InjectedFault(RuntimeError):
    """Base of the typed fault hierarchy. Instances carry the firing
    context (site / step / slot / uid) for reconciliation in tests."""

    def __init__(self, message: str = "", *, site: str = "?",
                 step: Optional[int] = None, slot: Optional[int] = None,
                 uid: Optional[int] = None):
        self.site, self.step, self.slot, self.uid = site, step, slot, uid
        super().__init__(
            message or f"{type(self).__name__} at {site!r} "
                       f"(step={step} slot={slot} uid={uid})")


class OomFault(InjectedFault):
    """Simulated RESOURCE_EXHAUSTED — routed to the graceful-degradation
    ladder (``ExecutionPlan.degrade``) by the serving engine."""


class NonFiniteFault(InjectedFault):
    """Non-finite values in a decode group's logits. When *injected*, the
    engine poisons the slot's KV rows with NaN so the in-trace guard
    catches it end to end; the same type is raised for organic NaNs."""


class StageTimeout(InjectedFault):
    """A pipeline stage exceeded its time budget (straggler)."""


class TransientDecodeFault(InjectedFault):
    """A transient, retryable decode failure (flaky interconnect, evicted
    host, preempted device) — the canonical RetryPolicy target."""


_FAULTS: dict[str, type[InjectedFault]] = {
    "oom": OomFault,
    "nonfinite": NonFiniteFault,
    "timeout": StageTimeout,
    "transient": TransientDecodeFault,
}

_SITES = ("prefill", "decode", "checkpoint.save")


def is_oom(err: BaseException) -> bool:
    """True for injected OOMs and for real accelerator OOMs (jax surfaces
    them as XlaRuntimeError with RESOURCE_EXHAUSTED in the message — string
    match keeps this module jax-free)."""
    if isinstance(err, OomFault):
        return True
    msg = str(err)
    return "RESOURCE_EXHAUSTED" in msg or "out of memory" in msg.lower()


# ---------------------------------------------------------------------------
# Specs + injector
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FireContext:
    """What a site knows when it fires — the argument of ``FaultSpec.pred``."""

    site: str
    step: Optional[int] = None
    slot: Optional[int] = None
    uid: Optional[int] = None
    attempt: int = 0
    plan: Any = None


@dataclass(frozen=True)
class FaultSpec:
    """One fault schedule entry: fire ``fault`` at ``site`` whenever every
    given predicate matches. ``None`` predicates match everything.

    ``after`` skips the first N eligible events, ``times`` caps total
    firings (``None`` = unlimited), ``p`` fires probabilistically from the
    injector's seeded stream, and ``pred`` is an arbitrary
    ``FireContext -> bool`` (e.g. fire only while the request's plan still
    has kernels enabled, so the degradation ladder terminates)."""

    fault: str
    site: str
    step: Optional[int] = None
    slot: Optional[int] = None
    uid: Optional[int] = None
    after: int = 0
    times: Optional[int] = 1
    p: float = 1.0
    pred: Optional[Callable[[FireContext], bool]] = None

    def __post_init__(self):
        if self.fault not in _FAULTS:
            raise ValueError(
                f"FaultSpec.fault={self.fault!r}: not in {sorted(_FAULTS)}")
        if self.site not in _SITES:
            raise ValueError(
                f"FaultSpec.site={self.site!r}: not in {_SITES}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"FaultSpec.p={self.p!r}: not in [0, 1]")


@dataclass
class _SpecState:
    eligible: int = 0
    fired: int = 0


class FaultInjector:
    """Evaluates FaultSpecs at fire sites; counts everything it does.

    ``counts`` maps fault class name -> total fired; ``events`` is the
    ordered log of fired faults (for reconciliation asserts). One injector
    is single-use state — build a fresh one per scenario."""

    def __init__(self, specs=(), *, seed: Optional[int] = None):
        if seed is None:
            from repro.exec import envcompat

            seed = envcompat.fault_seed() or 0
        self.seed = seed
        self.specs = tuple(specs)
        for s in self.specs:
            if not isinstance(s, FaultSpec):
                raise TypeError(f"expected FaultSpec, got {s!r}")
        self._rng = random.Random(seed)
        self._state = [_SpecState() for _ in self.specs]
        self.events: list[InjectedFault] = []

    @property
    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.events:
            name = type(f).__name__
            out[name] = out.get(name, 0) + 1
        return out

    @property
    def total_fired(self) -> int:
        return len(self.events)

    def fire(self, site: str, *, step: Optional[int] = None,
             slot: Optional[int] = None, uid: Optional[int] = None,
             attempt: int = 0, plan: Any = None) -> tuple[InjectedFault, ...]:
        """Faults fired for this event, in spec order (possibly empty)."""
        ctx = FireContext(site=site, step=step, slot=slot, uid=uid,
                          attempt=attempt, plan=plan)
        fired: list[InjectedFault] = []
        for spec, st in zip(self.specs, self._state):
            if spec.site != site:
                continue
            if spec.step is not None and spec.step != step:
                continue
            if spec.slot is not None and spec.slot != slot:
                continue
            if spec.uid is not None and spec.uid != uid:
                continue
            if spec.pred is not None and not spec.pred(ctx):
                continue
            st.eligible += 1
            if st.eligible <= spec.after:
                continue
            if spec.times is not None and st.fired >= spec.times:
                continue
            if spec.p < 1.0 and self._rng.random() >= spec.p:
                continue
            st.fired += 1
            fault = _FAULTS[spec.fault](site=site, step=step, slot=slot,
                                        uid=uid)
            fired.append(fault)
            self.events.append(fault)
        return tuple(fired)


# ---------------------------------------------------------------------------
# Scoping (mirrors exec.plan.use_plan)
# ---------------------------------------------------------------------------

_INJECTOR: ContextVar[Optional[FaultInjector]] = ContextVar(
    "repro_fault_injector", default=None)


def current_injector() -> Optional[FaultInjector]:
    """The innermost ``inject_faults`` scope's injector, else None."""
    return _INJECTOR.get()


def fire(site: str, **ctx) -> tuple[InjectedFault, ...]:
    """Module-level fire hook for instrumented sites: ``()`` outside any
    fault scope (the production fast path — one contextvar read)."""
    inj = _INJECTOR.get()
    if inj is None:
        return ()
    return inj.fire(site, **ctx)


@contextmanager
def inject_faults(*specs, seed: Optional[int] = None):
    """Scope a FaultInjector (re-entrant, exception-safe restore). Pass
    FaultSpecs (+ optional seed), or a single pre-built FaultInjector."""
    if len(specs) == 1 and isinstance(specs[0], FaultInjector):
        inj = specs[0]
    else:
        inj = FaultInjector(specs, seed=seed)
    token = _INJECTOR.set(inj)
    try:
        yield inj
    finally:
        _INJECTOR.reset(token)
