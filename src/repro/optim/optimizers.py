"""Optimizers in pure JAX: AdamW (AlphaFold's choice) and LAMB (large-batch,
paper §VI cites LAMB/LARS as the data-parallel-scaling tools).

Optimizer state is fp32 regardless of param dtype (mixed-precision master
copy lives in the fp32 `m`/`v` plus the fp32 params kept by TrainState).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def _zeros_like(params, dtype):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


def adamw_init(params, *, state_dtype=jnp.float32) -> OptState:
    """state_dtype=bfloat16 halves optimizer memory (beyond-paper lever used
    by the 236B config on the 256-chip mesh; update math stays fp32 — moments
    are cast up before use and down after)."""
    return OptState(jnp.zeros((), jnp.int32), _zeros_like(params, state_dtype),
                    _zeros_like(params, state_dtype))


def adamw_update(
    params,
    grads,
    state: OptState,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        sdt = m.dtype
        m = (b1 * m.astype(jnp.float32) + (1 - b1) * g)
        v = (b2 * v.astype(jnp.float32) + (1 - b2) * g * g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * update).astype(p.dtype),
                m.astype(sdt), v.astype(sdt))

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda x: x[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, OptState(step, new_m, new_v)


def lamb_init(params, *, state_dtype=jnp.float32) -> OptState:
    return adamw_init(params, state_dtype=state_dtype)


def lamb_update(
    params,
    grads,
    state: OptState,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
):
    """LAMB (You et al.): Adam direction with per-tensor trust ratio."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        sdt = m.dtype
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        r = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * pf
        w_norm = jnp.linalg.norm(pf)
        r_norm = jnp.linalg.norm(r)
        trust = jnp.where((w_norm > 0) & (r_norm > 0), w_norm / r_norm, 1.0)
        return (pf - lr * trust * r).astype(p.dtype), m.astype(sdt), v.astype(sdt)

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda x: x[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, OptState(step, new_m, new_v)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-6))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def make_optimizer(name: str) -> tuple[Callable, Callable]:
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "lamb":
        return lamb_init, lamb_update
    raise ValueError(f"unknown optimizer {name!r}")
