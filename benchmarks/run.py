"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_softmax       Fig. 8    fused softmax kernel
  bench_attention     §III.B    fused flash attention vs scores-materialized
  bench_triangle      §V        fused triangle-mult + OPM vs materialized
  bench_layernorm     Fig. 9    fused LayerNorm kernel
  bench_comm_volume   Table III DAP vs TP communication volume
  bench_mp_scaling    Fig. 10   model-parallel scaling (DAP vs TP), real devices
  bench_dp_scaling    Fig. 11 + Table IV  DP scaling + end-to-end cost model
  bench_inference     Figs 12-13 + Table V  inference latency + OOM frontier
  bench_duality       Fig. 7    duality-async overlap report from HLO
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_attention,
        bench_comm_volume,
        bench_dp_scaling,
        bench_duality,
        bench_inference,
        bench_layernorm,
        bench_mp_scaling,
        bench_softmax,
        bench_triangle,
    )

    print("name,us_per_call,derived")
    for mod in (bench_softmax, bench_attention, bench_triangle,
                bench_layernorm, bench_comm_volume, bench_mp_scaling,
                bench_dp_scaling, bench_inference, bench_duality):
        try:
            mod.run()
        except Exception as e:  # keep the harness going; failures are visible
            print(f"{mod.__name__},0,ERROR {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
