"""Yi-9B [arXiv:2403.04652]: llama-architecture dense GQA."""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="yi-9b", family="dense", source="arXiv:2403.04652",
    n_layers=48, d_model=4096, n_heads=32, n_kv=4, d_ff=11008, vocab=64000,
    rope_theta=10000.0,
)
REDUCED = reduced(CONFIG)
