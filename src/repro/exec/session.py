"""FastFold facade: bind ``(AlphaFoldConfig, ExecutionPlan)`` once, use it
everywhere.

    from repro.exec import ExecutionPlan, FastFold

    ff = FastFold(SMOKE, ExecutionPlan())
    params = ff.init(jax.random.PRNGKey(0))
    out = ff.forward(params, batch)                 # folding inference
    loss, metrics = ff.train_loss(params, batch, rng)
    outs = ff.serve(params, [batch_a, batch_b])     # per-request plans ok

The facade owns one jit wrapper per (plan, mode), so two plans can never
share a trace (the plan steers trace-time branches); the bound
ParallelPolicy provides the dist backend and, for the GSPMD backend, the
mesh scope around every call. ``examples/quickstart.py``,
``examples/train_alphafold_mini.py``, and the launch scripts drive the model
through this class instead of hand-threading ``dist=`` / ``hbm_budget=``.
"""
from __future__ import annotations

import contextlib

import jax

from repro.exec.plan import ExecutionPlan, current_plan, use_plan


def _mesh_scope(plan: ExecutionPlan):
    """Mesh context for the plan's dist backend (GSPMD needs the mesh active
    around trace and execution; the other backends need nothing)."""
    mesh = plan.parallel.mesh
    if plan.parallel.backend == "gspmd" and mesh is not None:
        return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
    return contextlib.nullcontext()


class FastFold:
    """AlphaFold bound to one ExecutionPlan (overridable per call)."""

    def __init__(self, config, plan: ExecutionPlan | None = None):
        self.config = config
        self.plan = plan if plan is not None else current_plan()
        self._jitted: dict = {}

    # -- params -------------------------------------------------------------

    def init(self, key):
        from repro.core.alphafold import init_alphafold

        with use_plan(self.plan):
            return init_alphafold(key, self.config)

    # -- composition hook ---------------------------------------------------

    @property
    def loss_fn(self):
        """Plain ``(params, batch, rng) -> alphafold_train_loss`` under the
        bound plan — hand this to train.loop.make_train_step (which jits the
        whole step itself)."""
        from repro.core.alphafold import alphafold_train_loss

        def fn(params, batch, rng):
            with use_plan(self.plan):
                return alphafold_train_loss(
                    params, batch, self.config, rng=rng,
                    dist=self.plan.parallel.make_dist())

        return fn

    # -- jitted entry points ------------------------------------------------

    def _get_jitted(self, kind: str, plan: ExecutionPlan, train: bool = False):
        key = (kind, plan, train)
        fn = self._jitted.get(key)
        if fn is not None:
            return fn
        from repro.core.alphafold import alphafold_forward, \
            alphafold_train_loss

        if kind == "forward":
            def impl(params, batch, rng):
                with use_plan(plan):
                    return alphafold_forward(
                        params, batch, self.config, rng=rng, train=train,
                        dist=plan.parallel.make_dist())
        else:
            def impl(params, batch, rng):
                with use_plan(plan):
                    return alphafold_train_loss(
                        params, batch, self.config, rng=rng,
                        dist=plan.parallel.make_dist())
        fn = jax.jit(impl)
        self._jitted[key] = fn
        return fn

    def forward(self, params, batch, *, rng=None, train: bool = False,
                plan: ExecutionPlan | None = None):
        """Full folding forward (recycling included) under the bound plan
        (or a per-call override)."""
        plan = plan if plan is not None else self.plan
        with _mesh_scope(plan):
            return self._get_jitted("forward", plan, train)(params, batch,
                                                            rng)

    def train_loss(self, params, batch, rng=None, *,
                   plan: ExecutionPlan | None = None):
        plan = plan if plan is not None else self.plan
        with _mesh_scope(plan):
            return self._get_jitted("train_loss", plan)(params, batch, rng)

    def serve(self, params, batches, *, plans=None):
        """Folding-inference service entry: run each request batch through
        ``forward``. ``plans`` (optional, same length) overrides the plan per
        request — e.g. an oracle-leg canary beside production pallas-leg
        requests — with one jit cache entry per distinct plan."""
        batches = list(batches)
        if plans is None:
            plans = [None] * len(batches)
        if len(plans) != len(batches):
            raise ValueError(
                f"serve: {len(batches)} batches but {len(plans)} plans")
        return [self.forward(params, b, plan=p)
                for b, p in zip(batches, plans)]
