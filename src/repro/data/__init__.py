from repro.data.synthetic import (  # noqa: F401
    LMBatch,
    ProteinBatch,
    lm_batches,
    protein_batches,
)
