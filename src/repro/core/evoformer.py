"""Evoformer (AlphaFold 2 trunk) with Dynamic Axial Parallelism.

One implementation, three execution modes via the ``dist`` backend
(core/dist.py). Sharding state machine (shard_map local view), following
paper Fig. 6:

  MSA rep   (B, s, r, Hm): sharded on **s** during row ops, swapped to **r**
            by all_to_all for column attention + Outer-Product-Mean, swapped
            back *after* OPM consumes it — the swap-back is launched before
            the pair stack and consumed at the next block's row attention,
            which is exactly the paper's Duality-Async overlap window.
  pair rep  (B, i, j, Hz): sharded on **i**; "incoming"/"ending-node" ops run
            on the all_to_all-transposed tensor (an axis swap, 1/N^2 volume).
  AllGather materializes cross-axis operands: OPM right projection, triangular
  left/right projections, and the (H, r, r) attention bias tensors.

Kernel usage (paper §IV.A + ScaleFold's fused-attention extension): all four
attention sites (MSA row, MSA col, triangle start/end) go through the
flash-style fused gated-attention Pallas kernel (``ops.fused_attention``) —
online softmax over KV tiles, so the (B, G, H, R, R) scores tensor never
reaches HBM. The pair stack's remaining hot paths go through the fused
triangle/OPM kernels (kernels/triangle.py): both triangular multiplicative
updates route ``dist.sharded_triangle`` (k-tiled product with the input
gating, pair mask, output LayerNorm and output gate fused into one sweep —
the (B, i, j, c) fp32 product never hits HBM at full size) and the
Outer-Product-Mean routes ``dist.sharded_opm`` (s-tiled outer product with
the fp32 mask-normalization and c²→d projection fused — no (B, i, j, c, c)
transient). Leg selection rides the context-local ExecutionPlan
(repro.exec.plan): ``KernelPolicy(enabled=False)`` (or out-of-envelope
shapes) sends every site to its materialized jnp path, kept for A/B and
diagnosis; ``KernelPolicy(triangle='oracle', opm='oracle')`` pins just the
triangle/OPM ops. All LayerNorms go through the fused LN kernel; gating
through bias+sigmoid+mul; residual adds through bias+dropout+add with the
AlphaFold shared-axis dropout mask. QKV and left/right projections use
merged GEMMs.

Chunk knobs (``inference_chunk``, ``opm_chunk``, ``attn_kv_tile``,
``tri_k_tile``, ``opm_s_tile``) default to 0 = off/kernel-default; the
AutoChunk planner (repro.memory.autochunk) fills them from the HBM budget at
the alphafold_forward level instead of hand-set constants.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import duality
from repro.core.dist import LocalDist
from repro.exec.plan import current_plan
from repro.kernels import ops
from repro.layers.attention import evoformer_attention, init_attention, AttnDims, \
    project_qkv, output_proj
from repro.layers.mlp import init_transition, transition
from repro.layers.norms import init_layer_norm, layer_norm
from repro.layers.params import Params, dense, init_dense

NEG_INF = -1e9


@dataclass(frozen=True)
class EvoformerConfig:
    d_msa: int = 256
    d_pair: int = 128
    msa_heads: int = 8
    pair_heads: int = 4
    head_dim: int = 32
    opm_dim: int = 32
    tri_mult_dim: int = 128
    transition_factor: int = 4
    dropout_msa: float = 0.15
    dropout_pair: float = 0.25
    n_blocks: int = 48
    compute_dtype: Any = jnp.bfloat16
    # remat policy for the block scan: "nothing" (recompute all, min memory)
    # or "dots" (save GEMM outputs: less recompute, more activation memory).
    remat_policy: str = "nothing"
    # OPM j-chunking: compute the (i, j, 32, 32) outer-product intermediate
    # in j-chunks of this size (0 = whole row at once). Shrinks the dominant
    # (B, i/N, r, 1024) intermediate by r/chunk (§Perf alphafold iter 2).
    opm_chunk: int = 0
    # Inference "chunking technique" (paper §V.C): the single-device fallback
    # AlphaFold/OpenFold use for long sequences — attention rows processed in
    # sequential chunks, capping the (G, H, r, r) transient. 0 = off. The
    # paper's point (Figs 12-13, Table V) is that DAP beats this; we implement
    # both so the comparison is ours to measure.
    inference_chunk: int = 0
    # KV tile for the fused flash-attention kernel (and its backward
    # recompute block). 0 = kernel default (512). Bounds the per-tile
    # attention transient at (B, G, H, r, kv_tile) instead of r^2.
    attn_kv_tile: int = 0
    # Tile of the fused triangle-multiplication kernel: the Pallas grid's k
    # accumulation tile and the XLA leg's / backward recompute's j output
    # block. 0 = leg default (Pallas 64, VMEM-budgeted; XLA/backward j block
    # 128 — the HBM-visible transient the planner models). Bounds the fp32
    # product transient at (B, i_loc, tile, c) instead of (B, i_loc, r, c).
    tri_k_tile: int = 0
    # Tile of the fused outer-product-mean kernel: Pallas s accumulation
    # tile / XLA-leg j output block / backward recompute block. 0 = leg
    # default (Pallas 64, XLA/backward 128). Bounds the fp32 outer-product
    # transient at (B, i_loc, tile, c_opm^2).
    opm_s_tile: int = 0
    # Let the AutoChunk planner (repro.memory.autochunk) fill any chunk knob
    # left at 0 from the HBM budget — resolved once per forward at the
    # alphafold_forward level (trace-time, static shapes). Hand-set nonzero
    # knobs are always respected.
    auto_chunk: bool = True


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_evoformer_block(key, cfg: EvoformerConfig) -> Params:
    ks = iter(jax.random.split(key, 24))
    d_m, d_z = cfg.d_msa, cfg.d_pair
    hm, hz, hd = cfg.msa_heads, cfg.pair_heads, cfg.head_dim
    c_mult = cfg.tri_mult_dim

    def attn(d_in, heads, d_out):
        return init_attention(
            next(ks), d_in, heads, heads, hd, gating=True, out_bias=True,
            d_out=d_out,
        )

    def tri_mult():
        return {
            "ln_in": init_layer_norm(d_z),
            # Merge GEMM (paper §IV.A.1): left+right projections and
            # left+right gates each fused into one weight.
            "proj": init_dense(next(ks), d_z, 2 * c_mult, bias=True),
            "gate": init_dense(next(ks), d_z, 2 * c_mult, bias=True),
            "ln_out": init_layer_norm(c_mult),
            "out": init_dense(next(ks), c_mult, d_z, bias=True, zero_init=True),
            "gate_out": init_dense(next(ks), d_z, d_z, bias=True),
        }

    def tri_attn():
        return {
            "ln": init_layer_norm(d_z),
            "bias": init_dense(next(ks), d_z, hz, bias=False),
            "attn": attn(d_z, hz, d_z),
        }

    return {
        "msa_row": {
            "ln_m": init_layer_norm(d_m),
            "ln_z": init_layer_norm(d_z),
            "bias": init_dense(next(ks), d_z, hm, bias=False),
            "attn": attn(d_m, hm, d_m),
        },
        "msa_col": {"ln": init_layer_norm(d_m), "attn": attn(d_m, hm, d_m)},
        "msa_trans": {"ln": init_layer_norm(d_m),
                      "mlp": init_transition(next(ks), d_m, cfg.transition_factor)},
        "opm": {
            "ln": init_layer_norm(d_m),
            "proj": init_dense(next(ks), d_m, 2 * cfg.opm_dim, bias=True),
            "out": init_dense(next(ks), cfg.opm_dim * cfg.opm_dim, d_z,
                              bias=True, zero_init=True),
        },
        "tri_mult_out": tri_mult(),
        "tri_mult_in": tri_mult(),
        "tri_attn_start": tri_attn(),
        "tri_attn_end": tri_attn(),
        "pair_trans": {"ln": init_layer_norm(d_z),
                       "mlp": init_transition(next(ks), d_z, cfg.transition_factor)},
    }


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def _residual_add(upd, residual, rate: float, rng, shared_axis: int,
                  train: bool):
    """AlphaFold shared-axis residual add: residual + dropout(upd) with one
    Bernoulli draw broadcast along ``shared_axis`` (row/column dropout),
    fused into the bias+dropout+add kernel in one HBM pass (paper §IV.A.1
    "JIT Fusion" residual chain). Under shard_map the mask is shared within
    the local shard when the shared axis is the sharded one
    (stochastic-regularization-equivalent; exact equivalence across dist
    modes is tested with dropout disabled)."""
    use_dropout = train and rate > 0.0 and rng is not None
    return ops.bias_dropout_add(
        upd, None, residual,
        rate=rate if use_dropout else 0.0,
        rng=rng if use_dropout else None,
        shared_axes=(shared_axis,),
    )


def _gated_attention(p_attn, x_n, bias, key_mask, dims: AttnDims,
                     dist=LocalDist(), chunk: int = 0, kv_tile: int = 0):
    """Group attention, kept 5D so (batch, group) dims never merge — merging
    two mesh-sharded dims would force an all-gather under GSPMD.

    x_n: (B, G, S, d); bias (B, H, S, S) shared across G, or None;
    key_mask (B, G, S) in {0,1}, or None. The G (group) dim carries the DAP
    shard; the q/ctx (fused path) or scores/probs (fallback) constraints pin
    it through the backward recompute regions, where plain propagation loses
    it.

    Fused path (default): ``dist.sharded_attention`` — the kernel-side
    sharding hook (core/dist.py). LocalDist/ShardMapDist call
    ops.fused_attention on the (already local) block; GspmdDist shard_maps
    the kernel over (batch_axes, 'model') so each device runs it on its
    local (B_loc, G_loc, S, H, D) shard with the gathered bias replicated —
    the production path executes the fused kernel instead of falling back.
    With kernels disabled on the plan (KernelPolicy(enabled=False) /
    attention='oracle'), out-of-envelope shapes, or a group dim that doesn't
    divide the mesh, the scores-materialized path below runs instead (A/B
    baseline; it never merges the (B, G) dims either).

    chunk > 0: the paper-§V.C chunking technique — G processed in sequential
    chunks, capping the attention transient at (B, chunk, H, S, *). Inference
    fallback only (trades latency for memory; DAP is the scalable answer).
    """
    def attend(x_c, mask_c):
        q, k, v = project_qkv(p_attn, x_c, dims, compute_dtype=x_c.dtype)
        hd = q.shape[-1]
        scale = 1.0 / (hd**0.5)
        mask = None
        bias_w = bias
        if bias_w is not None:
            # Duality-Async window: fence the gathered pair bias with the QKV
            # projection so the gather cannot sink past the independent GEMMs
            # to its consumer below (core/duality.py).
            bias_w, q = duality.overlap_window(bias_w, q)
        if mask_c is not None:
            mask = jnp.where(mask_c > 0, 0.0, NEG_INF).astype(jnp.float32)
        if (ops.fused_attention_supported(q.shape, kv_len=k.shape[2],
                                          dtype=q.dtype)
                and dist.sharded_attention_supported(q.shape)):
            spec = ("b", "m", None, None, None)
            q = dist.constrain(q, spec)
            k = dist.constrain(k, spec)
            v = dist.constrain(v, spec)
            ctx = dist.sharded_attention(q, k, v, bias=bias_w, mask=mask,
                                         scale=scale, kv_tile=kv_tile)
            ctx = dist.constrain(ctx, spec)
        else:
            # Sanctioned scores-materialized A/B fallback (oracle leg /
            # out-of-envelope shapes); the fused path above is production.
            # repro-lint: disable=R004
            scores = jnp.einsum("bgihd,bgjhd->bghij", q, k)
            scores = dist.constrain(scores, ("b", "m", None, None, None))
            # allow_flatten: under GspmdDist the (B, G) dims are mesh-sharded
            # GLOBAL dims — the softmax must not merge them even on TPU.
            probs = ops.fused_softmax(scores, bias=bias_w, mask=mask,
                                      scale=scale,
                                      allow_flatten=dist.local_tensors)
            probs = dist.constrain(probs, ("b", "m", None, None, None))
            ctx = jnp.einsum("bghij,bgjhd->bgihd", probs,
                             v)  # repro-lint: disable=R004 -- same fallback
        return output_proj(p_attn, ctx, x_for_gate=x_c)

    g = x_n.shape[1]
    if not chunk or g % chunk != 0 or chunk >= g:
        return attend(x_n, key_mask)
    nc = g // chunk

    def split(t):
        return t.reshape(t.shape[0], nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    if key_mask is None:
        out = jax.lax.map(lambda x: attend(x, None), split(x_n))
    else:
        out = jax.lax.map(lambda xm: attend(xm[0], xm[1]),
                          (split(x_n), split(key_mask)))
    return out.swapaxes(0, 1).reshape(x_n.shape[0], g, *out.shape[3:])


# ---------------------------------------------------------------------------
# Sub-modules (all take *local* tensors per the sharding state machine)
# ---------------------------------------------------------------------------

def msa_row_attention(p, msa, pair, seq_mask, dist, cfg: EvoformerConfig):
    """msa (B, s/N, r, Hm) [s-shard]; pair (B, i/N, j, Hz) [i-shard];
    seq_mask (B, r) replicated."""
    b, s_loc, r, _ = msa.shape
    dims = AttnDims(cfg.msa_heads, cfg.msa_heads, cfg.head_dim)
    # Pair bias: project local pair rows -> (B, i/N, j, H) -> gather rows.
    z_n = layer_norm(p["ln_z"], pair)
    bias_loc = dense(p["bias"], z_n)                      # (B, i/N, j, H)
    bias_loc = bias_loc.transpose(0, 3, 1, 2)             # (B, H, i/N, j)
    bias = dist.all_gather(bias_loc, axis=2)              # (B, H, r, r)
    bias = dist.constrain(bias, ("b", None, None, None))
    # Duality-async window: the gather result is first consumed *after* the
    # QKV projection below — independent compute the scheduler can overlap.
    m_n = layer_norm(p["ln_m"], msa)
    key_mask = jnp.broadcast_to(seq_mask[:, None, :], (b, s_loc, r))
    return _gated_attention(p["attn"], m_n, bias, key_mask, dims,
                            dist=dist, chunk=cfg.inference_chunk,
                            kv_tile=cfg.attn_kv_tile)


def msa_col_attention(p, msa, msa_mask, dist, cfg: EvoformerConfig):
    """msa (B, s, r/N, Hm) [r-shard]; msa_mask (B, s, r/N)."""
    b, s, r_loc, _ = msa.shape
    dims = AttnDims(cfg.msa_heads, cfg.msa_heads, cfg.head_dim)
    m_n = layer_norm(p["ln"], msa)
    x = m_n.transpose(0, 2, 1, 3)                  # (B, r/N, s, d)
    key_mask = msa_mask.transpose(0, 2, 1)         # (B, r/N, s)
    out = _gated_attention(p["attn"], x, None, key_mask, dims,
                           dist=dist, chunk=cfg.inference_chunk,
                           kv_tile=cfg.attn_kv_tile)
    return out.transpose(0, 2, 1, 3)


def msa_transition(p, msa):
    return transition(p["mlp"], layer_norm(p["ln"], msa))


def outer_product_mean(p, msa, msa_mask, dist, cfg: EvoformerConfig):
    """msa (B, s, r/N, Hm) [r-shard] -> pair update (B, i/N, j, Hz) [i-shard].

    Paper Fig. 6(b): the cross-axis operand is AllGathered; we gather the
    *right* projection so the output lands i-sharded, matching the pair rep.
    """
    c = cfg.opm_dim
    m_n = layer_norm(p["ln"], msa)
    ab = dense(p["proj"], m_n)                    # merged GEMM (B, s, r/N, 2c)
    a, bproj = jnp.split(ab, 2, axis=-1)
    mask = msa_mask[..., None].astype(a.dtype)
    a = a * mask
    bproj = bproj * mask
    b_full = dist.all_gather(bproj, axis=2)       # (B, s, r, c)
    b_full = dist.constrain(b_full, ("b", None, None, None))
    mask_full = dist.all_gather(msa_mask, axis=2)  # (B, s, r)
    # Duality-Async window: keep the left-projection operand inside the
    # gather's launch->use window (it is independent of the gather).
    b_full, a = duality.overlap_window(b_full, a)

    # Fused path (default): dist.sharded_opm — s-tiled accumulation of the
    # outer product with the fp32 mask-normalization and c²→Hz projection
    # fused, so the (B, i/N, r, c, c) transient never hits HBM at full size.
    # GspmdDist shard_maps the op over (batch_axes, 'model') with b_full
    # replicated. The j-chunked jnp path below stays as the A/B baseline
    # (plan legs: KernelPolicy(enabled=False) or opm='oracle').
    if (ops.fused_opm_supported(c, p["out"]["w"].shape[1], a.dtype)
            and dist.sharded_opm_supported(a.shape[2])):
        return dist.sharded_opm(a, b_full, msa_mask, mask_full,
                                p["out"]["w"], p["out"]["b"],
                                tile=cfg.opm_s_tile)

    def opm_block(b_blk, mask_blk):
        # repro-lint: disable=R004 -- sanctioned j-chunked OPM baseline
        o = jnp.einsum("bsic,bsjd->bijcd", a, b_blk)  # (B, r/N, jc, c, c)
        norm = jnp.einsum("bsi,bsj->bij", msa_mask,
                          mask_blk)  # repro-lint: disable=R004
        o = (o.astype(jnp.float32)
             / (norm[..., None, None] + 1e-3)).astype(a.dtype)
        o = o.reshape(o.shape[:3] + (c * c,))
        return dense(p["out"], o)                  # (B, i/N, jc, Hz)

    jc = cfg.opm_chunk
    r_full = b_full.shape[2]
    if not jc or r_full % jc != 0 or jc >= r_full:
        return opm_block(b_full, mask_full)
    # j-chunked: scan keeps the (i, jc, c*c) intermediate bounded.
    nb = r_full // jc
    bsz, s = b_full.shape[:2]
    b_c = b_full.reshape(bsz, s, nb, jc, c).transpose(2, 0, 1, 3, 4)
    m_c = mask_full.reshape(bsz, s, nb, jc).transpose(2, 0, 1, 3)
    _, outs = jax.lax.scan(
        lambda _, bm: (None, opm_block(bm[0], bm[1])), None, (b_c, m_c))
    # outs: (nb, B, i/N, jc, Hz) -> (B, i/N, r, Hz)
    return outs.transpose(1, 2, 0, 3, 4).reshape(bsz, a.shape[2], r_full, -1)


def triangle_mult_core(p, z_src, pair_mask_loc, dist,
                       cfg: EvoformerConfig):
    """Shared core of the two Triangular Multiplicative Updates: the full
    gated update (including the output gate) in ``z_src`` coords.

    z_src: tensor the a/b projections AND the output gate read (already
    LN'ed); for the "outgoing" update this is LN(z) (i-shard); for
    "incoming" it is the transposed LN(z) (row-sharded, transposed coords —
    the sigmoid output gate commutes elementwise with the transpose).

    Fused path (default): ``dist.sharded_triangle`` — k-tiled accumulation
    of the triangular product with the a-side input gating, pair mask,
    output LayerNorm and bias_sigmoid_mul output gate fused into the same
    sweep (ops.fused_triangle_mult); the b half is gated+masked *before*
    the row gather (elementwise commutes with the gather, and gathering the
    gated half keeps the collective at (B, r, k, c)). GspmdDist shard_maps
    the op over (batch_axes, 'model') with b_full replicated, so the
    kernel's tiling only ever sees local (B_loc, i_loc, ...) blocks. The
    materialized jnp path below stays behind the plan's oracle legs
    (KernelPolicy(enabled=False) / triangle='oracle') and out-of-envelope
    shapes for A/B.
    """
    c = cfg.tri_mult_dim
    ab = dense(p["proj"], z_src)                   # (B, p/N, k, 2c) merged
    g = dense(p["gate"], z_src)
    # Fused output gate operand: sigmoid(z @ Wg + bg) * upd, computed in the
    # same coords as the update (the gate bias rides into the fused op, so
    # dense() — which would apply it — cannot be used here).
    # repro-lint: disable=R004 -- d-scale GEMM, not an r²-scale contraction
    g_lin = jnp.einsum("...d,de->...e", z_src,
                       p["gate_out"]["w"].astype(z_src.dtype))
    if (ops.fused_triangle_supported(c, p["out"]["w"].shape[1], ab.dtype)
            and dist.sharded_triangle_supported(ab.shape[1])):
        a_lin, b_lin = jnp.split(ab, 2, axis=-1)
        ga, gb = jnp.split(g, 2, axis=-1)
        bm = (b_lin.astype(jnp.float32)
              * jax.nn.sigmoid(gb.astype(jnp.float32))).astype(ab.dtype)
        bm = bm * pair_mask_loc[..., None].astype(ab.dtype)
        b_full = dist.all_gather(bm, axis=1)       # (B, r, k, c) gather rows
        b_full = dist.constrain(b_full, ("b", None, None, None))
        # Duality-Async window: fence the a-side operand with the gather so
        # the triangular gather cannot sink to the fused product below.
        b_full, a_lin = duality.overlap_window(b_full, a_lin)
        return dist.sharded_triangle(
            a_lin, ga, pair_mask_loc, b_full,
            p["ln_out"]["gamma"], p["ln_out"]["beta"],
            p["out"]["w"], p["out"]["b"], g_lin, p["gate_out"]["b"],
            tile=cfg.tri_k_tile)
    # Materialized A/B path: gated projections and the (B, p/N, r, c)
    # product as standalone tensors, then LN -> projection -> gate.
    ab = ab * jax.nn.sigmoid(g.astype(jnp.float32)).astype(ab.dtype)
    ab = ab * pair_mask_loc[..., None].astype(ab.dtype)
    a, bm = jnp.split(ab, 2, axis=-1)
    b_full = dist.all_gather(bm, axis=1)           # (B, r, k, c) gather rows
    b_full = dist.constrain(b_full, ("b", None, None, None))
    b_full, a = duality.overlap_window(b_full, a)
    # repro-lint: disable=R004 -- sanctioned materialized triangle A/B path
    o = jnp.einsum("bikc,bjkc->bijc", a, b_full)   # (B, p/N, r, c)
    upd = dense(p["out"], layer_norm(p["ln_out"], o))
    # Fused gating kernel: sigmoid(z @ Wg + bg) * upd in one HBM pass.
    return ops.bias_sigmoid_mul(g_lin, p["gate_out"]["b"], upd)


def triangle_mult_outgoing(p, pair, pair_mask_loc, dist, cfg):
    z_n = layer_norm(p["ln_in"], pair)
    return triangle_mult_core(p, z_n, pair_mask_loc, dist, cfg)


def triangle_mult_incoming(p, pair, pair_t, pair_mask_loc_t, dist, cfg):
    """incoming(z)_ij = sum_k a_ki b_kj == outgoing_core(z^T)_ij.

    pair:   (B, i/N, j, Hz) — kept for signature compatibility (TP mode);
            the gate now reads the transposed coords directly.
    pair_t: (B, j/N, i, Hz) — transposed tensor (from all_to_all axis swap).

    The whole gated update is computed in transposed coords (gate(z^T) =
    gate(z)^T elementwise) and axis-swapped back to i-shard coords.
    """
    del pair
    z_n_t = layer_norm(p["ln_in"], pair_t)
    upd_t = triangle_mult_core(p, z_n_t, pair_mask_loc_t, dist, cfg)
    return transpose_pair(upd_t, dist)


def triangle_attention(p, pair, seq_mask, dist, cfg: EvoformerConfig):
    """Around starting node on (B, i/N, j, Hz): per-row attention over k with
    bias b(j,k); bias rows are local -> AllGather."""
    b, i_loc, r, _ = pair.shape
    dims = AttnDims(cfg.pair_heads, cfg.pair_heads, cfg.head_dim)
    z_n = layer_norm(p["ln"], pair)
    bias_loc = dense(p["bias"], z_n).transpose(0, 3, 1, 2)  # (B, H, i/N, k)
    bias = dist.all_gather(bias_loc, axis=2)                # (B, H, r, r)
    bias = dist.constrain(bias, ("b", None, None, None))
    key_mask = jnp.broadcast_to(seq_mask[:, None, :], (b, i_loc, r))
    return _gated_attention(p["attn"], z_n, bias, key_mask, dims,
                            dist=dist, chunk=cfg.inference_chunk,
                            kv_tile=cfg.attn_kv_tile)


def transpose_pair(x, dist):
    """Axis-swap a pair-like tensor: (B, i/N, j, c) -> (B, j/N, i, c).

    all_to_all moves the shard (1/N^2 volume, paper Table III), local swap
    finishes the transpose."""
    y = dist.all_to_all(x, split_axis=2, concat_axis=1)  # (B, i, j/N, c)
    y = y.swapaxes(1, 2)
    return dist.constrain(y, ("b", "m") + (None,) * (y.ndim - 2))


# ---------------------------------------------------------------------------
# Full block
# ---------------------------------------------------------------------------

def evoformer_block(
    params: Params,
    msa: jax.Array,        # (B, s/N, r, Hm)  s-shard
    pair: jax.Array,       # (B, i/N, j, Hz)  i-shard
    msa_mask: jax.Array,   # (B, s/N, r)
    seq_mask: jax.Array,   # (B, r) replicated
    pair_mask_loc: jax.Array,  # (B, i/N, j)
    *,
    dist=None,
    cfg: EvoformerConfig,
    rng=None,
    train: bool = False,
):
    """One Evoformer block under the DAP sharding state machine.
    ``dist=None`` resolves the current ExecutionPlan's ParallelPolicy."""
    if dist is None:
        dist = current_plan().parallel.make_dist()
    rngs = list(jax.random.split(rng, 8)) if rng is not None else [None] * 8

    # ----- MSA stack (s-shard phase) -----
    msa = dist.constrain(msa, ("b", "m", None, None))
    pair = dist.constrain(pair, ("b", "m", None, None))
    upd = msa_row_attention(params["msa_row"], msa, pair, seq_mask, dist, cfg)
    msa = _residual_add(upd, msa, cfg.dropout_msa, rngs[0], 2, train)

    # all_to_all #1: s-shard -> r-shard.
    msa = dist.all_to_all(msa, split_axis=2, concat_axis=1)
    msa = dist.constrain(msa, ("b", None, "m", None))
    msa_mask_r = dist.all_to_all(msa_mask, split_axis=2, concat_axis=1)

    upd = msa_col_attention(params["msa_col"], msa, msa_mask_r, dist, cfg)
    msa = _residual_add(upd, msa, 0.0, None, 0, train)
    msa = _residual_add(msa_transition(params["msa_trans"], msa), msa,
                        0.0, None, 0, train)

    # ----- Communication: OPM consumes the r-shard MSA -----
    pair_upd = outer_product_mean(params["opm"], msa, msa_mask_r, dist, cfg)

    # all_to_all #2 (the Duality-Async window): swap MSA back to s-shard now;
    # its result is consumed only at the *next block's* row attention, so the
    # entire pair stack below is overlap-eligible compute.
    msa = dist.all_to_all(msa, split_axis=1, concat_axis=2)
    msa = dist.constrain(msa, ("b", "m", None, None))

    pair = _residual_add(pair_upd, pair, cfg.dropout_pair, rngs[1], 1, train)

    # ----- Pair stack (i-shard phase) -----
    upd = triangle_mult_outgoing(params["tri_mult_out"], pair, pair_mask_loc,
                                 dist, cfg)
    pair = _residual_add(upd, pair, cfg.dropout_pair, rngs[2], 1, train)

    pair_t = transpose_pair(pair, dist)
    pair_mask_t = transpose_pair(pair_mask_loc[..., None], dist)[..., 0]
    upd = triangle_mult_incoming(params["tri_mult_in"], pair, pair_t,
                                 pair_mask_t, dist, cfg)
    pair = _residual_add(upd, pair, cfg.dropout_pair, rngs[3], 1, train)

    upd = triangle_attention(params["tri_attn_start"], pair, seq_mask, dist, cfg)
    pair = _residual_add(upd, pair, cfg.dropout_pair, rngs[4], 1, train)

    # Ending-node attention == starting-node attention on the transpose.
    pair_t = transpose_pair(pair, dist)
    upd_t = triangle_attention(params["tri_attn_end"], pair_t, seq_mask, dist, cfg)
    upd = transpose_pair(upd_t, dist)
    pair = _residual_add(upd, pair, cfg.dropout_pair, rngs[5], 2, train)

    pair = _residual_add(
        transition(params["pair_trans"]["mlp"],
                   layer_norm(params["pair_trans"]["ln"], pair)),
        pair, 0.0, None, 0, train)
    # Duality-Async window (paper §IV.C): the swap-back all_to_all above is
    # consumed only at the *next* block's row attention. Fencing its result
    # with the finished pair stack pins the collective inside this block —
    # the scheduler may start it as early as OPM allows but cannot sink it
    # into the next block's body past the overlap-eligible pair compute.
    msa, pair = duality.overlap_window(msa, pair)
    return msa, pair


def init_evoformer_stack(key, cfg: EvoformerConfig) -> Params:
    """Stacked block params with leading layer axis (scan-compatible)."""
    keys = jax.random.split(key, cfg.n_blocks)
    return jax.vmap(lambda k: init_evoformer_block(k, cfg))(keys)


def evoformer_stack(
    params_stacked: Params,
    msa: jax.Array,
    pair: jax.Array,
    msa_mask: jax.Array,
    seq_mask: jax.Array,
    pair_mask_loc: jax.Array,
    *,
    dist=None,
    cfg: EvoformerConfig,
    rng=None,
    train: bool = False,
    remat: bool = True,
):
    """scan over n_blocks Evoformer blocks (activation checkpointing per block,
    as AlphaFold/the paper do — §III.B "gradient checkpointing").
    ``dist=None`` resolves the current ExecutionPlan's ParallelPolicy."""
    if dist is None:
        dist = current_plan().parallel.make_dist()
    rngs = (jax.random.split(rng, cfg.n_blocks) if rng is not None
            else jnp.zeros((cfg.n_blocks, 2), jnp.uint32))

    def body(carry, xs):
        m, z = carry
        p, r_key = xs
        r = r_key if rng is not None else None
        m, z = evoformer_block(p, m, z, msa_mask, seq_mask, pair_mask_loc,
                               dist=dist, cfg=cfg, rng=r, train=train)
        return (m, z), None

    if remat:
        policy = (jax.checkpoint_policies.dots_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)
    (msa, pair), _ = jax.lax.scan(body, (msa, pair), (params_stacked, rngs))
    return msa, pair
