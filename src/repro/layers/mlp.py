"""Feed-forward blocks: SwiGLU (LLM) and Transition (Evoformer 2-layer MLP)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.params import Params, init_dense, dense


def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        # Merge-GEMM: gate and up projections fused into one weight.
        "wi": init_dense(k1, d_model, 2 * d_ff, bias=False, dtype=dtype),
        "wo": init_dense(k2, d_ff, d_model, bias=False, zero_init=True, dtype=dtype),
    }


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    gu = jnp.einsum("...d,de->...e", x, p["wi"]["w"].astype(dt))
    g, u = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    return jnp.einsum("...e,eo->...o", h, p["wo"]["w"].astype(dt))


def init_gelu_mlp(key, d_model: int, d_ff: int, *, bias: bool = True,
                  dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "wi": init_dense(k1, d_model, d_ff, bias=bias, dtype=dtype),
        "wo": init_dense(k2, d_ff, d_model, bias=bias, zero_init=True, dtype=dtype),
    }


def gelu_mlp(p: Params, x: jax.Array) -> jax.Array:
    h = dense(p["wi"], x)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    return dense(p["wo"], h)


# Evoformer "Transition": LN -> Linear(4x) -> ReLU -> Linear. The LN lives in
# the caller; AlphaFold uses ReLU here.
def init_transition(key, d: int, factor: int = 4, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "wi": init_dense(k1, d, factor * d, bias=True, dtype=dtype),
        "wo": init_dense(k2, factor * d, d, bias=True, zero_init=True, dtype=dtype),
    }


def transition(p: Params, x: jax.Array) -> jax.Array:
    h = dense(p["wi"], x)
    h = jax.nn.relu(h)
    return dense(p["wo"], h)
