"""Compiled-program contracts: declarative invariants over lowered artifacts.

A `Contract` is a small frozen object whose ``check(artifact)`` returns
`Violation`s found in a `CompiledArtifact` — the plain-data view of one
``jit(...).lower().compile()`` result (HLO text + ``memory_analysis()`` peak
+ static collective counts). Cells (repro/analysis/cells.py) build artifacts
for a matrix of (config, ExecutionPlan preset, mesh) programs; this module
stays jax-free so contracts evaluate against canned HLO in tests and the
runner can parse args before any backend initializes.

The four contracts (full rationale in ``repro/analysis/__init__``):

  NoMergedAllGather   no all-gather result whose leading dim is a merged
                      (B*G)/(B*I) extent — the flatten-forced-gather
                      regression PR 2/3 eliminated.
  NoInvoluntaryRemat  no all-gather feeding a dynamic-slice in the same
                      computation — the static signature of GSPMD
                      materializing a full tensor only to re-slice it
                      (resharding via full rematerialization).
  CollectiveBudget    static collective-op count stays within a per-block
                      budget (the scan body is traced once, so counts are
                      per-block already).
  PeakBytesWithin     XLA's actually-allocated peak agrees with AutoChunk's
                      transient-bytes model within a factor, both ways —
                      the cross-validation that keeps the admission-control
                      model honest.

``assert_no_merged_allgather`` is the shared test-side entry point: the
distributed tests and the CI contract matrix call the same finder, so they
cannot drift apart.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.roofline.analysis import count_collective_ops

# ---------------------------------------------------------------------------
# Pure HLO finders
# ---------------------------------------------------------------------------

# An all-gather definition with its result shape: `= f32[32,16,8]{...} all-gather(`
# (also matches the async `all-gather-start` form; `-done` re-states the
# operand name, not a new gather).
_AG_DEF_RE = re.compile(
    r"=\s*(?:\(\s*)?\w+\[([0-9,]+)\][^=]*? all-gather(?:-start)?\(")


def find_merged_allgathers(hlo_text: str, merged_leads, min_rank: int = 3):
    """All-gather result shapes whose leading dim is one of ``merged_leads``
    (with rank >= min_rank): the signature of a flatten that merged a
    mesh-sharded (batch, group) pair and forced GSPMD to gather the whole
    representation. Returns the offending dim lists."""
    leads = set(merged_leads)
    bad = []
    for m in _AG_DEF_RE.finditer(hlo_text):
        dims = [int(x) for x in m.group(1).split(",") if x]
        if len(dims) >= min_rank and dims[0] in leads:
            bad.append(dims)
    return bad


def assert_no_merged_allgather(hlo_text: str, merged_leads,
                               min_rank: int = 3) -> None:
    """Shared test-side assertion (tests/test_distributed.py and the CI
    contract matrix both call this one finder)."""
    bad = find_merged_allgathers(hlo_text, merged_leads, min_rank)
    assert not bad, (
        f"merged-dim all-gather(s) producing lead dims {sorted(merged_leads)} "
        f"(rank >= {min_rank}): {bad}")


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=.*?\s([\w\-]+)\(")


def find_gather_then_slice(hlo_text: str):
    """(gather_name, slice_line) pairs where an all-gather's result is
    consumed by a dynamic-slice in the same computation — XLA materialized
    the full tensor only to slice a shard back out (involuntary full
    rematerialization of the gathered operand; the compile-time warning has
    no HLO marker, so this is its static signature)."""
    pairs = []
    gathered_in_comp: set[str] = set()
    for line in hlo_text.splitlines():
        if line.strip() == "}":
            gathered_in_comp = set()     # computation boundary
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, op = m.group(1), m.group(2)
        if op in ("all-gather", "all-gather-start", "all-gather-done"):
            gathered_in_comp.add(name)
        elif op == "dynamic-slice" and gathered_in_comp:
            for operand in re.findall(r"%([\w.\-]+)", line.split("(", 1)[1]):
                if operand in gathered_in_comp:
                    pairs.append((operand, line.strip()))
                    break
    return pairs


# ---------------------------------------------------------------------------
# Artifact + contracts
# ---------------------------------------------------------------------------


@dataclass
class CompiledArtifact:
    """Plain-data view of one lowered+compiled program (or jaxpr cell).

    ``peak_bytes`` is ``memory_analysis()``'s peak (None when the backend
    reports none); ``collective_counts`` may be pre-filled (the jaxpr cell
    counts primitives, no HLO) and is otherwise derived from the text."""

    name: str
    hlo_text: str = ""
    peak_bytes: int | None = None
    collective_counts: dict | None = None

    def counts(self) -> dict:
        if self.collective_counts is None:
            self.collective_counts = count_collective_ops(self.hlo_text)
        return self.collective_counts


@dataclass(frozen=True)
class Violation:
    contract: str
    artifact: str
    message: str

    def render(self) -> str:
        return f"{self.artifact}: {self.contract}: {self.message}"


@dataclass(frozen=True)
class NoMergedAllGather:
    """No all-gather may produce a merged-lead tensor (see
    ``find_merged_allgathers``)."""

    merged_leads: frozenset
    min_rank: int = 3
    name: str = field(default="NoMergedAllGather", init=False)

    def check(self, art: CompiledArtifact) -> list[Violation]:
        bad = find_merged_allgathers(art.hlo_text, self.merged_leads,
                                     self.min_rank)
        return [Violation(self.name, art.name,
                          f"all-gather produces merged-lead shape {dims} "
                          f"(leads {sorted(self.merged_leads)}, "
                          f"rank >= {self.min_rank})")
                for dims in bad]


@dataclass(frozen=True)
class NoInvoluntaryRemat:
    """No gather-then-slice resharding (see ``find_gather_then_slice``)."""

    name: str = field(default="NoInvoluntaryRemat", init=False)

    def check(self, art: CompiledArtifact) -> list[Violation]:
        return [Violation(self.name, art.name,
                          f"all-gather %{g} rematerializes a full tensor "
                          f"then re-slices it: {line[:120]}")
                for g, line in find_gather_then_slice(art.hlo_text)]


@dataclass(frozen=True)
class CollectiveBudget:
    """Total static collective-op count <= max_per_block * blocks. The layer
    scan's body is traced once, so the HLO count for an N-block stack IS the
    per-block count (blocks=1); pass blocks>1 for unrolled programs."""

    max_per_block: int
    blocks: int = 1
    name: str = field(default="CollectiveBudget", init=False)

    def check(self, art: CompiledArtifact) -> list[Violation]:
        counts = art.counts()
        total = sum(counts.values())
        budget = self.max_per_block * self.blocks
        if total <= budget:
            return []
        return [Violation(self.name, art.name,
                          f"{total} collective ops > budget {budget} "
                          f"({self.max_per_block}/block x {self.blocks}): "
                          f"{counts}")]


@dataclass(frozen=True)
class PeakBytesWithin:
    """XLA's allocated peak within ``factor`` of the AutoChunk model, both
    directions: compiled <= modeled*factor (the model is not lying low —
    admission control would over-admit) AND modeled <= compiled*factor (the
    model is not crying wolf — plans would over-serialize). Factors are
    per-cell, calibrated on the checked-in BENCH_contracts.json baseline."""

    modeled_bytes: int
    factor: float
    name: str = field(default="PeakBytesWithin", init=False)

    def check(self, art: CompiledArtifact) -> list[Violation]:
        if art.peak_bytes is None:
            return [Violation(self.name, art.name,
                              "backend reported no memory_analysis() peak")]
        peak = art.peak_bytes
        lo = self.modeled_bytes / self.factor
        hi = self.modeled_bytes * self.factor
        if lo <= peak <= hi:
            return []
        return [Violation(
            self.name, art.name,
            f"compiled peak {peak} outside modeled {self.modeled_bytes} "
            f"x factor {self.factor} (allowed [{int(lo)}, {int(hi)}], "
            f"ratio {peak / max(self.modeled_bytes, 1):.3f})")]


def check_all(contracts, art: CompiledArtifact) -> list[Violation]:
    out: list[Violation] = []
    for c in contracts:
        out.extend(c.check(art))
    return out
