"""End-to-end AlphaFold model tests (reduced config)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.alphafold import SMOKE
from repro.core.alphafold import (
    alphafold_forward,
    alphafold_train_loss,
    init_alphafold,
)
from repro.core.losses import fape, true_frames_from_ca
from repro.core.structure import (
    compose_frames,
    frames_apply,
    frames_invert_apply,
    identity_frames,
    quat_to_rot,
)
from repro.data import protein_batches


@pytest.fixture(scope="module")
def batch():
    pb = next(protein_batches(batch=2, n_seq=6, n_res=12, seed=0))
    return {k: jnp.asarray(getattr(pb, k)) for k in
            ("msa", "msa_mask", "residue_index", "aatype", "seq_mask",
             "pseudo_beta", "bert_mask", "true_msa")}


@pytest.fixture(scope="module")
def params():
    return init_alphafold(jax.random.PRNGKey(0), SMOKE)


def test_forward_shapes(params, batch):
    out = alphafold_forward(params, batch, SMOKE)
    b, s, r = batch["msa"].shape
    assert out["coords"].shape == (b, r, 3)
    assert out["msa_logits"].shape == (b, s, r, 23)
    assert out["distogram_logits"].shape == (b, r, r, 64)
    assert not bool(jnp.isnan(out["coords"]).any())


def test_recycling_changes_output(params, batch):
    # coords are zero at init (zero-init backbone updates), so compare the
    # recycled representations/heads instead.
    o0 = alphafold_forward(params, batch, SMOKE, n_recycle=0)
    o2 = alphafold_forward(params, batch, SMOKE, n_recycle=2)
    d = float(jnp.max(jnp.abs(o0["distogram_logits"] - o2["distogram_logits"])))
    assert d > 1e-6


def test_loss_and_grads_finite(params, batch):
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: alphafold_train_loss(p, batch, SMOKE,
                                       rng=jax.random.PRNGKey(1)),
        has_aux=True)(params)
    assert np.isfinite(float(loss))
    for k in ("fape", "masked_msa", "distogram"):
        assert np.isfinite(float(metrics[k]))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


# --- rigid-frame math -------------------------------------------------------

def test_quat_identity():
    rot = quat_to_rot(jnp.array([1.0, 0, 0, 0]))
    np.testing.assert_allclose(np.asarray(rot), np.eye(3), atol=1e-6)


def test_frames_roundtrip():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (5, 4))
    rot = quat_to_rot(q)
    trans = jax.random.normal(jax.random.PRNGKey(1), (5, 3))
    pts = jax.random.normal(jax.random.PRNGKey(2), (5, 7, 3))
    there = frames_apply(rot, trans, pts)
    back = frames_invert_apply(rot, trans, there)
    np.testing.assert_allclose(np.asarray(back), np.asarray(pts), atol=1e-5)


def test_compose_frames_associative():
    qs = jax.random.normal(jax.random.PRNGKey(0), (3, 4))
    ts = jax.random.normal(jax.random.PRNGKey(1), (3, 3))
    rots = [quat_to_rot(q) for q in qs]
    r12, t12 = compose_frames(rots[0], ts[0], rots[1], ts[1])
    ra, ta = compose_frames(r12, t12, rots[2], ts[2])
    r23, t23 = compose_frames(rots[1], ts[1], rots[2], ts[2])
    rb, tb = compose_frames(rots[0], ts[0], r23, t23)
    np.testing.assert_allclose(np.asarray(ra), np.asarray(rb), atol=1e-5)
    np.testing.assert_allclose(np.asarray(ta), np.asarray(tb), atol=1e-5)


def test_fape_rigid_invariance():
    """FAPE(x, x transformed by a global rigid motion) == 0."""
    coords = jnp.asarray(
        np.random.default_rng(0).normal(size=(1, 12, 3)) * 5, jnp.float32)
    rot, trans = true_frames_from_ca(coords)
    g_rot = quat_to_rot(jnp.array([0.5, 0.2, -0.3, 0.8]))
    g_t = jnp.array([1.0, -2.0, 3.0])
    coords2 = jnp.einsum("ij,brj->bri", g_rot, coords) + g_t
    rot2, trans2 = true_frames_from_ca(coords2)
    mask = jnp.ones((1, 12))
    err = fape(rot2, trans2, rot, trans, coords2, coords, mask)
    assert float(err) < 1e-4


def test_fape_positive_for_wrong_structure():
    coords = jnp.asarray(
        np.random.default_rng(0).normal(size=(1, 12, 3)) * 5, jnp.float32)
    other = jnp.asarray(
        np.random.default_rng(1).normal(size=(1, 12, 3)) * 5, jnp.float32)
    rot, trans = true_frames_from_ca(coords)
    rot2, trans2 = true_frames_from_ca(other)
    mask = jnp.ones((1, 12))
    assert float(fape(rot2, trans2, rot, trans, other, coords, mask)) > 0.05
