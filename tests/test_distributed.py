"""Distributed-equivalence tests (paper-faithful DAP + TP baseline).

These run in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count
set *before* jax import, keeping the main test process at 1 device.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(script: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


DAP_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, evoformer_stack
from repro.core.dap import dap_evoformer_stack, shard_dap_inputs
cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2, head_dim=8,
                      opm_dim=8, tri_mult_dim=16, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B,s,r = 2,8,12
msa = jax.random.normal(jax.random.PRNGKey(1),(B,s,r,cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2),(B,r,r,cfg.d_pair))
masks = (jnp.ones((B,s,r)), jnp.ones((B,r)), jnp.ones((B,r,r)))
m_ref, p_ref = evoformer_stack(params, msa, pair, *masks, cfg=cfg, remat=False)
from repro.launch.mesh import _mesh
mesh = _mesh((1,4), ("data","model"))
fn = jax.jit(dap_evoformer_stack(mesh, cfg, remat=False))
args = shard_dap_inputs(mesh, msa, pair, *masks)
m_dap, p_dap = fn(params, *args)
np.testing.assert_allclose(np.asarray(m_dap), np.asarray(m_ref), atol=3e-5)
np.testing.assert_allclose(np.asarray(p_dap), np.asarray(p_ref), atol=3e-5)
import re
txt = fn.lower(params, *args).compile().as_text()
n_a2a = len(re.findall(r"all-to-all", txt))
n_ag = len(re.findall(r"all-gather", txt))
assert n_a2a > 0 and n_ag > 0, (n_a2a, n_ag)
print("DAP_OK", n_a2a, n_ag)
"""


TP_SCRIPT = r"""
import re, numpy as np, jax, jax.numpy as jnp
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, evoformer_stack
from repro.core.tp import tp_evoformer_stack
cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2, head_dim=8,
                      opm_dim=8, tri_mult_dim=16, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B,s,r = 2,6,10
msa = jax.random.normal(jax.random.PRNGKey(1),(B,s,r,cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2),(B,r,r,cfg.d_pair))
masks = (jnp.ones((B,s,r)), jnp.ones((B,r)), jnp.ones((B,r,r)))
m_ref, p_ref = evoformer_stack(params, msa, pair, *masks, cfg=cfg, remat=False)
from repro.launch.mesh import _mesh
mesh = _mesh((1,2), ("data","model"))
fn = jax.jit(tp_evoformer_stack(mesh, cfg, remat=False))
m_tp, p_tp = fn(params, msa, pair, *masks)
np.testing.assert_allclose(np.asarray(m_tp), np.asarray(m_ref), atol=3e-5)
np.testing.assert_allclose(np.asarray(p_tp), np.asarray(p_ref), atol=3e-5)
txt = fn.lower(params, msa, pair, *masks).compile().as_text()
# count all-reduce OPS (result definitions), not name mentions — newer XLA
# text repeats the op name on operand references.
n_ar = len(re.findall(r"= \S+ all-reduce\(", txt)) or \
    len(re.findall(r"all-reduce", txt))
# paper Table III: 6 AllReduce in the forward pass per block
assert n_ar == 6, n_ar
print("TP_OK", n_ar)
"""


LM_GSPMD_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.decoder import init_model, lm_loss
cfg = get_config("qwen2-1.5b", reduced_variant=True)
params = init_model(jax.random.PRNGKey(0), cfg)
B, S = 4, 32
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
batch = {"tokens": toks, "targets": toks, "mask": jnp.ones((B, S))}
loss_ref, _ = lm_loss(params, batch, cfg)
from repro.launch.mesh import _mesh
mesh = _mesh((2, 2), ("data", "model"))
def shard_x(x):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P("data", "model", None)))
with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
    loss_sharded, _ = jax.jit(
        lambda p, b: lm_loss(p, b, cfg, shard_x=shard_x))(params, batch)
np.testing.assert_allclose(float(loss_sharded), float(loss_ref), rtol=1e-4)
print("GSPMD_LM_OK", float(loss_sharded))
"""


MINI_DRYRUN_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.configs import get_config, INPUT_SHAPES
import repro.launch.dryrun as dr
import dataclasses
from repro.launch.mesh import _mesh
mesh = _mesh((2, 4), ("data", "model"))
cfg = get_config("qwen2-1.5b", reduced_variant=True)
shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=64, global_batch=4)
fn, args, in_sh, out_sh = dr.build_train(cfg, shape, mesh)
with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
    compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
mem = compiled.memory_analysis()
assert mem is not None
from repro.roofline import analysis
flops, bts = analysis.hlo_cost(compiled.as_text())
assert flops > 0 and bts > 0
print("MINI_DRYRUN_OK", flops > 0)
"""


SHARDED_ATTN_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.dap import dap_evoformer_stack, shard_dap_inputs
from repro.core.dist import GspmdDist, LocalDist
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, \
    evoformer_stack
from repro.kernels import ops
from repro.exec.plan import current_plan
from repro.launch.mesh import _mesh

cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2,
                      head_dim=8, opm_dim=8, tri_mult_dim=16, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B, s, r = 2, 8, 16   # s and r divide every tested device count
msa = jax.random.normal(jax.random.PRNGKey(1), (B, s, r, cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2), (B, r, r, cfg.d_pair))
masks = (jnp.ones((B, s, r)), jnp.ones((B, r)), jnp.ones((B, r, r)))
n_dev = len(jax.devices())

def outputs_loss(m, z):
    return jnp.sum(m ** 2) + jnp.sum(z ** 2)

m_ref, z_ref = evoformer_stack(params, msa, pair, *masks, cfg=cfg,
                               remat=False)
g_ref = jax.grad(lambda p: outputs_loss(*evoformer_stack(
    p, msa, pair, *masks, cfg=cfg, remat=False)))(params)

def check_close(got, want, tag):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=1e-4, err_msg=tag)

def check_grads(g, tag):
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        check_close(a, b, tag)

mesh = _mesh((1, n_dev), ("data", "model"))

# ---- paper-faithful DAP (ShardMapDist): kernel runs on local shards ----
fn = dap_evoformer_stack(mesh, cfg, remat=False)
args = shard_dap_inputs(mesh, msa, pair, *masks)
m, z = jax.jit(fn)(params, *args)
check_close(m, m_ref, "dap fwd msa"); check_close(z, z_ref, "dap fwd pair")
g = jax.jit(jax.grad(lambda p: outputs_loss(*fn(p, *args))))(params)
check_grads(g, "dap grad")
print("DAP_ATTN_OK", n_dev)

# ---- production path (GspmdDist): kernel shard_mapped over the mesh ----
calls = [0]
orig = GspmdDist.sharded_attention
def counting(self, *a, **kw):
    calls[0] += 1
    return orig(self, *a, **kw)
GspmdDist.sharded_attention = counting
dist = GspmdDist(mesh=mesh, axis="model")
with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
    fwd = jax.jit(lambda p: evoformer_stack(p, msa, pair, *masks, dist=dist,
                                            cfg=cfg, remat=False))
    m, z = fwd(params)
    check_close(m, m_ref, "gspmd fwd msa")
    check_close(z, z_ref, "gspmd fwd pair")
    g = jax.jit(jax.grad(lambda p: outputs_loss(*evoformer_stack(
        p, msa, pair, *masks, dist=dist, cfg=cfg, remat=False))))(params)
    check_grads(g, "gspmd grad")
    hlo = fwd.lower(params).compile().as_text()

if current_plan().kernels.enabled:
    # all four attention sites took the shard-mapped fused path (the scan
    # body is traced once regardless of n_blocks)
    assert calls[0] >= 4 and calls[0] % 4 == 0, calls
    print("GSPMD_FUSED_SITES_OK", calls[0])

# No all-gather may produce a merged-(B*G, ...) tensor: the old flatten
# forced GSPMD to gather the whole representation before the kernel. Same
# finder as the CI contract matrix's NoMergedAllGather (repro.analysis) —
# the test and the gate cannot drift apart.
from repro.analysis.contracts import assert_no_merged_allgather
assert_no_merged_allgather(hlo, {B * s, B * r}, min_rank=4)
print("GSPMD_ATTN_OK", n_dev)
"""


TRIANGLE_DIST_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.dist import (GspmdDist, LocalDist, ShardMapDist,
                             shard_map_compat)
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, \
    evoformer_stack
from repro.kernels import ops
from repro.exec.plan import current_plan
from repro.launch.mesh import _mesh

n_dev = len(jax.devices())
B, I, J, K, C, D, S = 2, 16, 16, 16, 16, 12, 8
ks = jax.random.split(jax.random.PRNGKey(0), 12)
a_lin = jax.random.normal(ks[0], (B, I, K, C))
ga = jax.random.normal(ks[1], (B, I, K, C))
mask = jax.random.bernoulli(ks[2], 0.7, (B, I, K)).astype(jnp.float32)
b_full = jax.random.normal(ks[3], (B, J, K, C))
gamma = jax.random.normal(ks[4], (C,)); beta = jax.random.normal(ks[5], (C,))
w_out = jax.random.normal(ks[6], (C, D)); b_out = jax.random.normal(ks[7], (D,))
g_lin = jax.random.normal(ks[8], (B, I, J, D))
g_bias = jax.random.normal(ks[9], (D,))
targs = (a_lin, ga, mask, b_full, gamma, beta, w_out, b_out, g_lin, g_bias)

oa = jax.random.normal(ks[10], (B, S, I, 8))
ob = jax.random.normal(ks[11], (B, S, J, 8))
oma = jax.random.bernoulli(ks[0], 0.8, (B, S, I)).astype(jnp.float32)
omb = jax.random.bernoulli(ks[1], 0.8, (B, S, J)).astype(jnp.float32)
oa = oa * oma[..., None]; ob = ob * omb[..., None]
ow = jax.random.normal(ks[2], (64, D)); obias = jax.random.normal(ks[3], (D,))
oargs = (oa, ob, oma, omb, ow, obias)

loc = LocalDist()
tri_ref = loc.sharded_triangle(*targs, tile=4)
opm_ref = loc.sharded_opm(*oargs, tile=4)
tri_g_ref = jax.grad(lambda a, b: jnp.sum(loc.sharded_triangle(
    a, *targs[1:3], b, *targs[4:], tile=4) ** 2), argnums=(0, 1))(
    a_lin, b_full)
opm_g_ref = jax.grad(lambda a, b: jnp.sum(loc.sharded_opm(
    a, b, *oargs[2:], tile=4) ** 2), argnums=(0, 1))(oa, ob)

def close(got, want, tag):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=1e-4, err_msg=tag)

mesh = _mesh((1, n_dev), ("data", "model"))

# ---- GspmdDist: shard-mapped fused pair-stack ops, fwd + grad + HLO ----
dist = GspmdDist(mesh=mesh, axis="model")
with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
    fwd_tri = jax.jit(lambda a, b: dist.sharded_triangle(
        a, *targs[1:3], b, *targs[4:], tile=4))
    close(fwd_tri(a_lin, b_full), tri_ref, "gspmd tri fwd")
    g = jax.jit(jax.grad(lambda a, b: jnp.sum(
        dist.sharded_triangle(a, *targs[1:3], b, *targs[4:], tile=4) ** 2),
        argnums=(0, 1)))(a_lin, b_full)
    close(g[0], tri_g_ref[0], "gspmd tri da")
    close(g[1], tri_g_ref[1], "gspmd tri db")
    fwd_opm = jax.jit(lambda a, b: dist.sharded_opm(a, b, *oargs[2:],
                                                    tile=4))
    close(fwd_opm(oa, ob), opm_ref, "gspmd opm fwd")
    go = jax.jit(jax.grad(lambda a, b: jnp.sum(
        dist.sharded_opm(a, b, *oargs[2:], tile=4) ** 2),
        argnums=(0, 1)))(oa, ob)
    close(go[0], opm_g_ref[0], "gspmd opm da")
    close(go[1], opm_g_ref[1], "gspmd opm db")
    hlo = fwd_tri.lower(a_lin, b_full).compile().as_text()
    hlo += jax.jit(jax.grad(lambda a, b: jnp.sum(dist.sharded_triangle(
        a, *targs[1:3], b, *targs[4:], tile=4) ** 2), argnums=(0, 1))
        ).lower(a_lin, b_full).compile().as_text()

# No all-gather may produce a merged-(B*I, ...) tensor (the op's internal
# j-block scan must run on local shards, not a gathered representation).
# Same finder as the CI contract matrix's NoMergedAllGather.
from repro.analysis.contracts import assert_no_merged_allgather
assert_no_merged_allgather(hlo, {B * I, B * J}, min_rank=3)
print("GSPMD_TRI_OK", n_dev)

# ---- ShardMapDist: ops on explicit local shards inside shard_map ----
smd = ShardMapDist(axis="model")
row4 = P(None, "model", None, None)
rep = lambda x: P(*([None] * x.ndim))
tri_sm = shard_map_compat(
    lambda a, g_, mk, bf, gl: smd.sharded_triangle(
        a, g_, mk, bf, gamma, beta, w_out, b_out, gl, g_bias, tile=4),
    mesh, (row4, row4, P(None, "model", None), rep(b_full), row4), row4)
close(jax.jit(tri_sm)(a_lin, ga, mask, b_full, g_lin), tri_ref, "smd tri")
opm_sm = shard_map_compat(
    lambda a, bf, ma, mb: smd.sharded_opm(a, bf, ma, mb, ow, obias, tile=4),
    mesh, (P(None, None, "model", None), rep(ob), P(None, None, "model"),
           rep(omb)), row4)
close(jax.jit(opm_sm)(oa, ob, oma, omb), opm_ref, "smd opm")
print("SMD_TRI_OK", n_dev)

# ---- production evoformer routes the pair stack through the hooks ----
calls = {"tri": 0, "opm": 0}
orig_tri = GspmdDist.sharded_triangle
orig_opm = GspmdDist.sharded_opm
def counting_tri(self, *a, **kw):
    calls["tri"] += 1
    return orig_tri(self, *a, **kw)
def counting_opm(self, *a, **kw):
    calls["opm"] += 1
    return orig_opm(self, *a, **kw)
GspmdDist.sharded_triangle = counting_tri
GspmdDist.sharded_opm = counting_opm
cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2,
                      head_dim=8, opm_dim=8, tri_mult_dim=16, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B2, s, r = 2, 8, 16
msa = jax.random.normal(jax.random.PRNGKey(1), (B2, s, r, cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2), (B2, r, r, cfg.d_pair))
masks = (jnp.ones((B2, s, r)), jnp.ones((B2, r)), jnp.ones((B2, r, r)))
m_ref, z_ref = evoformer_stack(params, msa, pair, *masks, cfg=cfg,
                               remat=False)
dist2 = GspmdDist(mesh=mesh, axis="model")
with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
    m, z = jax.jit(lambda p: evoformer_stack(
        p, msa, pair, *masks, dist=dist2, cfg=cfg, remat=False))(params)
close(m, m_ref, "evo msa"); close(z, z_ref, "evo pair")
if current_plan().kernels.enabled:
    # 2 triangle sites + 1 OPM site per block (scan body traced once)
    assert calls["tri"] >= 2 and calls["tri"] % 2 == 0, calls
    assert calls["opm"] >= 1, calls
    print("GSPMD_PAIR_SITES_OK", calls["tri"], calls["opm"])
print("EVO_TRI_OK", n_dev)
"""


DUALITY_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.core.dap import dap_evoformer_stack, shard_dap_inputs
from repro.core.duality import overlap_report
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack
from repro.launch.mesh import _mesh
cfg = EvoformerConfig(d_msa=32, d_pair=16, msa_heads=4, pair_heads=2,
                      head_dim=8, opm_dim=8, tri_mult_dim=16, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B, s, r = 1, 8, 16
msa = jax.random.normal(jax.random.PRNGKey(1), (B, s, r, cfg.d_msa))
pair = jax.random.normal(jax.random.PRNGKey(2), (B, r, r, cfg.d_pair))
masks = (jnp.ones((B, s, r)), jnp.ones((B, r)), jnp.ones((B, r, r)))
mesh = _mesh((1, 4), ("data", "model"))
fn = jax.jit(dap_evoformer_stack(mesh, cfg, remat=False))
args = shard_dap_inputs(mesh, msa, pair, *masks)
txt = fn.lower(params, *args).compile().as_text()
rep = overlap_report(txt)
# The wired overlap_window (evoformer block end / bias gathers) must leave a
# non-empty Duality-Async window: on backends with async collectives, at
# least one start/done pair has compute inside it; backends that schedule
# collectives synchronously (XLA:CPU) report sync_collectives only.
assert (rep["pairs_with_compute_between"] >= 1
        or (rep["pairs"] == 0 and rep["sync_collectives"] > 0)), rep
print("DUALITY_WINDOW_OK", rep)
"""


@pytest.mark.slow
def test_dap_shard_map_equals_local_oracle():
    assert "DAP_OK" in run_sub(DAP_SCRIPT, devices=4)


@pytest.mark.slow
@pytest.mark.parametrize("devices", [2, 4, 8])
def test_sharded_fused_attention_parity(devices):
    """fwd + jax.grad parity of the shard-mapped fused-attention paths vs the
    LocalDist oracle on 2/4/8-device host meshes, for both ShardMapDist
    (paper DAP) and GspmdDist (production), plus the no-merged-all-gather
    HLO assertion."""
    out = run_sub(SHARDED_ATTN_SCRIPT, devices=devices)
    assert f"DAP_ATTN_OK {devices}" in out
    assert f"GSPMD_ATTN_OK {devices}" in out


@pytest.mark.slow
@pytest.mark.parametrize("devices", [2, 4, 8])
def test_sharded_triangle_opm_parity(devices):
    """fwd + jax.grad parity of the shard-mapped fused triangle/OPM ops vs
    the LocalDist oracle on 2/4/8-device host meshes, for both GspmdDist
    (production) and ShardMapDist (paper DAP), plus the
    no-merged-all-gather HLO assertion and the evoformer-site routing
    check."""
    out = run_sub(TRIANGLE_DIST_SCRIPT, devices=devices)
    assert f"GSPMD_TRI_OK {devices}" in out
    assert f"SMD_TRI_OK {devices}" in out
    assert f"EVO_TRI_OK {devices}" in out


@pytest.mark.slow
def test_duality_overlap_window_certified():
    """Regression for the wired duality.overlap_window: the lowered 2-block
    DAP stack certifies a non-empty async overlap window (or, on backends
    without async collective pairs, that the collectives are synchronous —
    not sunk-and-merged away)."""
    assert "DUALITY_WINDOW_OK" in run_sub(DUALITY_SCRIPT, devices=4)


@pytest.mark.slow
def test_tp_equals_local_oracle_and_allreduce_count():
    assert "TP_OK 6" in run_sub(TP_SCRIPT, devices=2)


@pytest.mark.slow
def test_gspmd_lm_loss_matches_single_device():
    assert "GSPMD_LM_OK" in run_sub(LM_GSPMD_SCRIPT, devices=4)


@pytest.mark.slow
def test_mini_dryrun_compiles_and_analyzes():
    assert "MINI_DRYRUN_OK" in run_sub(MINI_DRYRUN_SCRIPT, devices=8)
