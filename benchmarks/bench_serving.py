"""Measured serving benchmark: a mixed-length synthetic trace through the
instrumented ServingEngine, per ExecutionPlan preset — the first *measured*
(not static) perf-trajectory artifact.

For each preset the driver scopes a fresh obs tracer, submits a seeded
mixed-length prompt trace (lengths drawn across [4, max_seq/2] so prefill
cost and slot turnover actually vary), drains the engine, and aggregates
the event stream with ``repro.obs.report``. The checked-in
``BENCH_serving.json`` rows are keyed by the row's full serialized
ExecutionPlan (``plan.to_dict()`` — never the process-salted hash) and
carry the measured p50/p95/p99 queued->done latency, tokens/sec, mean slot
occupancy, jit-entry census, and the roofline-referenced hardware
efficiency per phase. ``python -m repro.obs report --bench`` (CI leg 8)
schema-validates both the JSONL stream and this payload.

Smoke mode (``--smoke``) shrinks the trace for the CI gate; the artifact
records which mode produced it so trend tooling never compares smoke
against full rows.

Usage:
    python benchmarks/bench_serving.py --smoke --out BENCH_serving.json \
        --events-out /tmp/obs_serving.jsonl
"""
import argparse
import json
import time

import numpy as np


def make_trace(n_requests: int, max_seq: int, seed: int) -> list:
    """Seeded mixed-length synthetic prompts (vocab ids below 500 like the
    resilience harness; lengths spread over [4, max_seq // 2])."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(4, max(5, max_seq // 2 + 1), size=n_requests)
    return [rng.integers(0, 500, size=(int(n),)) for n in lengths]


def bench_preset(name, plan, params, cfg, prompts, *, n_slots, max_seq,
                 max_new):
    from repro.obs import aggregate, hardware_efficiency, use_tracer
    from repro.serving.engine import ServingEngine

    with use_tracer() as tr:
        eng = ServingEngine(params, cfg, n_slots=n_slots, max_seq=max_seq,
                            plan=plan)
        t0 = time.perf_counter()
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        finished = eng.run()
        wall_s = time.perf_counter() - t0

    assert all(r.status == "done" for r in finished), \
        f"bench preset {name}: not every request finished clean"
    events = tr.events_resolved()
    agg = aggregate(events)
    tokens = agg["counters"].get("tokens", 0.0)
    occ = agg["gauges"].get("occupancy", {})
    row = {
        "preset": name,
        "plan": plan.to_dict(),
        "requests": len(prompts),
        "tokens": tokens,
        "wall_s": round(wall_s, 4),
        "tokens_per_s": round(tokens / wall_s, 2) if wall_s else 0.0,
        "latency_ms": {k: round(v, 3) for k, v in
                       agg["requests"]["latency_ms"].items()},
        "occupancy_mean": round(occ.get("mean", 0.0), 3),
        "occupancy_hist": occ.get("hist", {}),
        "jit_entries": agg["jit"],
        "efficiency": {
            phase: {k: (round(v, 6) if isinstance(v, float) else v)
                    for k, v in e.items()}
            for phase, e in hardware_efficiency(agg).items()},
    }
    return row, tr


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--smoke", action="store_true",
                        help="CI-sized trace (fast, artifact marked smoke)")
    parser.add_argument("--requests", type=int, default=None,
                        help="trace length (default 16, smoke 6)")
    parser.add_argument("--slots", type=int, default=2)
    parser.add_argument("--max-seq", type=int, default=24)
    parser.add_argument("--max-new", type=int, default=None,
                        help="tokens per request (default 8, smoke 3)")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument("--presets", default="default,oracle",
                        help="comma-separated ExecutionPlan preset names")
    parser.add_argument("--out", default="BENCH_serving.json")
    parser.add_argument("--events-out", default=None,
                        help="also dump the last preset's JSONL stream here")
    args = parser.parse_args(argv)

    n_requests = args.requests or (6 if args.smoke else 16)
    max_new = args.max_new or (3 if args.smoke else 8)

    import jax

    from repro.configs import get_config
    from repro.exec.plan import preset
    from repro.models.decoder import init_model
    from repro.obs.report import BENCH_SCHEMA_VERSION, validate_bench

    cfg = get_config("qwen2-1.5b", reduced_variant=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = make_trace(n_requests, args.max_seq, args.seed)

    rows, last_tracer = [], None
    for name in args.presets.split(","):
        name = name.strip()
        row, last_tracer = bench_preset(
            name, preset(name), params, cfg, prompts, n_slots=args.slots,
            max_seq=args.max_seq, max_new=max_new)
        rows.append(row)
        lat = row["latency_ms"]
        print(f"{name:16s} {row['tokens']:.0f} tok in {row['wall_s']:.2f}s "
              f"({row['tokens_per_s']:.1f} tok/s)  latency p50/p95/p99 "
              f"{lat['p50']:.1f}/{lat['p95']:.1f}/{lat['p99']:.1f} ms  "
              f"occupancy {row['occupancy_mean']:.2f}")

    payload = {
        "schema": BENCH_SCHEMA_VERSION,
        "smoke": bool(args.smoke),
        "model": cfg.name,
        "n_slots": args.slots,
        "max_seq": args.max_seq,
        "max_new_tokens": max_new,
        "requests": n_requests,
        "seed": args.seed,
        "rows": rows,
    }
    problems = validate_bench(payload)
    assert not problems, f"self-check failed: {problems}"
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote {args.out} ({len(rows)} row(s))")

    if args.events_out:
        n = last_tracer.dump_jsonl(args.events_out)
        print(f"wrote {args.events_out} ({n} events)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
