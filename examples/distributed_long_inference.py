"""Paper Figs 12-13 story: Dynamic-Axial-Parallel distributed inference over
long sequences — per-device activation memory drops ~linearly with DAP degree,
which is what lets FastFold fold >3k-residue proteins that OOM single-device.

Runs the DAP Evoformer on 4 simulated host devices:

  PYTHONPATH=src python examples/distributed_long_inference.py --n-res 96
"""
import argparse
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

INNER = r"""
import time, jax, jax.numpy as jnp
from repro.core.evoformer import EvoformerConfig, init_evoformer_stack, evoformer_stack
from repro.core.dap import dap_evoformer_stack, shard_dap_inputs
N_RES = {n_res}
cfg = EvoformerConfig(d_msa=64, d_pair=32, msa_heads=4, pair_heads=2, head_dim=16,
                      opm_dim=16, tri_mult_dim=32, n_blocks=2)
params = init_evoformer_stack(jax.random.PRNGKey(0), cfg)
B, s = 1, 8
msa = jax.random.normal(jax.random.PRNGKey(1), (B, s, N_RES, cfg.d_msa), jnp.bfloat16)
pair = jax.random.normal(jax.random.PRNGKey(2), (B, N_RES, N_RES, cfg.d_pair), jnp.bfloat16)
masks = (jnp.ones((B, s, N_RES)), jnp.ones((B, N_RES)), jnp.ones((B, N_RES, N_RES)))
ndev = len(jax.devices())
mesh = jax.make_mesh((1, ndev), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
fn = jax.jit(dap_evoformer_stack(mesh, cfg, remat=False))
args = shard_dap_inputs(mesh, msa, pair, *masks)
compiled = fn.lower(params, *args).compile()
mem = compiled.memory_analysis()
t0 = time.time(); out = fn(params, *args); jax.block_until_ready(out)
print(f"devices={{ndev}} n_res={{N_RES}} "
      f"per-device peak activation bytes={{mem.peak_memory_in_bytes:,}} "
      f"wall={{time.time()-t0:.2f}}s")
"""


def run(ndev: int, n_res: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", INNER.format(n_res=n_res)],
                         env=env, capture_output=True, text=True, timeout=900)
    print(out.stdout.strip() or out.stderr[-400:])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-res", type=int, default=96)
    args = ap.parse_args()
    print("DAP distributed inference — per-device memory vs DAP degree")
    for ndev in (1, 2, 4):
        run(ndev, args.n_res)


if __name__ == "__main__":
    main()
