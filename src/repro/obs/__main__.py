"""``python -m repro.obs report EVENTS.jsonl [--bench BENCH.json] [--strict]``

Validates an event stream against the stable schema, renders the
aggregated report (span percentiles + self-time, request lifecycle
tallies, occupancy histograms, jit-entry churn, roofline-referenced
hardware-efficiency fractions), and checks the request-lifecycle
reconciliation invariant. With ``--bench`` it additionally schema-checks
a BENCH_serving.json payload. ``--strict`` turns any schema or
reconciliation problem into a nonzero exit (the CI leg-8 mode).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.events import read_jsonl, validate_events
from repro.obs.report import reconcile, render_report, validate_bench


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = parser.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report", help="render a report over a JSONL "
                                        "event stream")
    rep.add_argument("events", help="JSONL file from Tracer.dump_jsonl")
    rep.add_argument("--bench", default=None,
                     help="also schema-validate this BENCH_serving.json")
    rep.add_argument("--strict", action="store_true",
                     help="exit nonzero on any schema/reconcile problem")
    args = parser.parse_args(argv)

    events = read_jsonl(args.events)
    problems = [f"schema: {p}" for p in validate_events(events)]
    print(render_report(events))
    problems += [f"reconcile: {p}" for p in reconcile(events)]

    if args.bench is not None:
        with open(args.bench, encoding="utf-8") as fh:
            payload = json.load(fh)
        bench_problems = validate_bench(payload)
        problems += [f"bench: {p}" for p in bench_problems]
        if not bench_problems:
            print(f"bench: {args.bench} valid "
                  f"({len(payload['rows'])} row(s))")

    for p in problems:
        print(f"PROBLEM {p}", file=sys.stderr)
    if problems:
        print(f"{len(problems)} problem(s)", file=sys.stderr)
        return 1 if args.strict else 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
