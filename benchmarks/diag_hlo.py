"""Diagnostic: compile one (arch, shape [, overrides]) and dump the top
byte/flop-contributing HLO ops with their loop scales — the 'profile' the
§Perf hypothesis loop reads (there is no wall-clock profiler for the TPU
target on this host; the lowered IR is the evidence).

  PYTHONPATH=src python -m benchmarks.diag_hlo --arch deepseek-v2-236b \
      --shape train_4k --top 25 [--set attn_q_block=0]
"""
import argparse
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

INNER = r"""
# dryrun sets the 512-device XLA flag (via exec/envcompat) before jax init;
# the materialized-path HLO comes from a use_plan("oracle") scope, not env.
import re, jax, dataclasses
from repro.launch import dryrun
from repro.exec.plan import preset, use_plan
from repro.roofline import analysis as A

arch, shape_name, top_n = {arch!r}, {shape!r}, {top}
overrides = {overrides!r}
mesh = dryrun.make_production_mesh()
if arch.startswith("alphafold"):
    fn, args, in_sh, out_sh = dryrun.build_alphafold(arch.split("-")[1], mesh,
                                                     evo_overrides=overrides)
    kind = "train"
else:
    cfg = dryrun.get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = dryrun.INPUT_SHAPES[shape_name]
    kind = shape.kind
    fn, args, in_sh, out_sh = dryrun.BUILDERS[kind](cfg, shape, mesh)
with jax.set_mesh(mesh), use_plan(preset("oracle")):
    compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
txt = compiled.as_text()
comps = A._split_computations(txt)
scales = A._execution_scales(comps)
fused = set()
for lines in comps.values():
    for ln in lines:
        if " fusion(" in ln:
            for m in re.finditer(r"calls=%?([\w.\-]+)", ln):
                fused.add(m.group(1))
fe = {{n: A._fusion_param_effective(comps[n]) for n in fused if n in comps}}
fo = {{n: A._fusion_root_out_bytes(comps[n]) for n in fused if n in comps}}
rows_b, rows_f = [], []
for name, lines in comps.items():
    sc = max(scales.get(name, 1.0), 1.0)
    st = A._symbols(lines)
    isfused = name in fused or name.startswith("fused")
    for ln in lines:
        if " dot(" in ln:
            f = A._dot_flops(ln, st) * sc
            if f > 0:
                rows_f.append((f, sc, name, ln.strip()[:110]))
        if isfused or any(op in ln for op in A._SKIP_BYTE_OPS) or "=" not in ln:
            continue
        b = A._op_bytes(ln, st, fe, fo) * sc
        if b > 0:
            rows_b.append((b, sc, name, ln.strip()[:110]))
print("==== TOP BYTES ====")
for b, sc, name, ln in sorted(rows_b, reverse=True)[:top_n]:
    print(f"{{b/2**30:9.1f}}GB x{{sc:7.0f}} {{name[:30]:30s}} {{ln}}")
print("==== TOP FLOPS ====")
for f, sc, name, ln in sorted(rows_f, reverse=True)[:top_n]:
    print(f"{{f/1e12:9.2f}}TF x{{sc:7.0f}} {{name[:30]:30s}} {{ln}}")
print("==== COLLECTIVE PAYLOADS ====")
st = A.parse_collectives(txt, mesh.shape["model"])
for k, v in sorted(st.payload_bytes.items(), key=lambda kv: -kv[1]):
    print(f"{{v/2**30:9.1f}}GB payload {{k}} (count {{st.counts[k]}})")
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (int/bool)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=")
        overrides[k] = (v == "True") if v in ("True", "False") else int(v)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", INNER.format(arch=args.arch, shape=args.shape,
                                            top=args.top,
                                            overrides=overrides)],
        env=env, text=True, timeout=7200)
    sys.exit(out.returncode)


if __name__ == "__main__":
    main()
